//! Transfer-time computation with two-lane NIC contention.
//!
//! Every node has one NIC.  Bulk transfers (≥ eager threshold) occupy
//! the NIC FIFO-style: a new bulk transfer starts when both endpoint
//! NICs are free, and occupies them for its serialization time — this
//! is what produces the contention the paper observes when many drains
//! read from few nodes (160→20, §V-C).  Small latency-sensitive
//! messages use a priority lane: they see at most
//! `small_lane_max_wait` of queueing behind bulk traffic, modelling the
//! virtual-lane/QoS behaviour of InfiniBand and MPICH's separate
//! control path.

use super::calibration::NetParams;
use super::topology::Placement;
use crate::simcluster::Time;

/// How a transfer is driven (affects CPU charge, not wire time).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransferClass {
    /// Two-sided send/recv: sender CPU packs, receiver CPU unpacks.
    TwoSided,
    /// One-sided Get: origin initiates; target CPU is not involved.
    Rma,
}

/// Outcome of routing one message through the model.
#[derive(Clone, Copy, Debug)]
pub struct TransferTiming {
    /// When the initiating CPU is free again (software + pack cost).
    pub cpu_done: Time,
    /// When the payload is fully available at the destination.
    pub arrival: Time,
}

/// Cost of `MPI_Intercomm_merge` over `nd` final ranks: ⌈log2 ND⌉
/// rounds of context agreement at `merge_round` seconds each.
pub fn intercomm_merge_cost(p: &NetParams, nd: usize) -> f64 {
    let rounds = usize::BITS - (nd.max(2) - 1).leading_zeros();
    p.merge_round * rounds as f64
}

/// Virtual-time decomposition of one `MPI_Comm_spawn` + intercomm-merge
/// phase (MaM's *Merge* grow path).  All offsets are seconds past the
/// spawn collective's entry synchronization.
///
/// * [`SpawnSchedule::atomic`] is the legacy single-constant model: all
///   sources blocked for one opaque duration and the spawned ranks come
///   up atomically when the sources resume — bit-identical to the
///   pre-subsystem behaviour.
/// * [`SpawnSchedule::parallel`] decomposes the phase into launch
///   latency + per-wave process startup + merge, with every source root
///   launching its share of the targets concurrently; spawned ranks
///   come up at staggered times, one wave at a time.
/// * [`SpawnSchedule::asynchronous`] initiates the same parallel launch
///   but unblocks the sources right after the launch handshake: the
///   targets finish starting (and merging) while the sources are
///   already registering windows / draining — the spawn phase overlaps
///   the redistribution's own initialization.
#[derive(Clone, Debug, PartialEq)]
pub struct SpawnSchedule {
    /// Seconds until the spawn root resumes and the merged communicator
    /// becomes available to the sources.
    pub initiate: f64,
    /// Seconds every source rank stays blocked in the spawn collective.
    /// Equals `initiate` for Async; covers launch + startup waves +
    /// merge for Parallel.
    pub source_block: f64,
    /// Per-spawned-rank start offsets (index = spawn order).  Empty
    /// means the legacy atomic behaviour: children begin when the
    /// sources resume.
    pub child_up: Vec<f64>,
}

impl SpawnSchedule {
    /// The legacy model: one opaque constant, atomic start.
    pub fn atomic(dur: f64) -> SpawnSchedule {
        SpawnSchedule { initiate: dur, source_block: dur, child_up: Vec::new() }
    }

    /// Start offset of spawned rank `j` when `ns` roots launch
    /// `n_new` targets round-robin: wave `j / ns`, each wave costing
    /// one per-process startup.
    fn wave_up(p: &NetParams, ns: usize, j: usize) -> f64 {
        p.spawn_launch + (j / ns.max(1) + 1) as f64 * p.spawn_per_proc
    }

    /// Parallel spawning: all `ns` sources act as spawn roots, each
    /// launching ⌈n_new/ns⌉ targets; sources block through the merge.
    pub fn parallel(p: &NetParams, ns: usize, n_new: usize, nd: usize) -> SpawnSchedule {
        let waves = n_new.div_ceil(ns.max(1));
        let merge = intercomm_merge_cost(p, nd);
        SpawnSchedule {
            initiate: p.spawn_launch,
            source_block: p.spawn_launch + waves as f64 * p.spawn_per_proc + merge,
            child_up: (0..n_new).map(|j| Self::wave_up(p, ns, j)).collect(),
        }
    }

    /// Asynchronous spawning: the same parallel launch, but sources
    /// resume after the launch handshake; targets complete startup and
    /// the merge in the background (their first collective on the
    /// merged communicator synchronizes with the sources naturally).
    pub fn asynchronous(p: &NetParams, ns: usize, n_new: usize, nd: usize) -> SpawnSchedule {
        let merge = intercomm_merge_cost(p, nd);
        SpawnSchedule {
            initiate: p.spawn_launch,
            source_block: p.spawn_launch,
            child_up: (0..n_new).map(|j| Self::wave_up(p, ns, j) + merge).collect(),
        }
    }

    /// Latest spawned-rank start offset (0 for the atomic model).
    pub fn last_child_up(&self) -> f64 {
        self.child_up.iter().fold(0.0, |a, &b| a.max(b))
    }
}

/// Expected extra spawn-phase seconds spent on failed launch attempts
/// under per-attempt failure probability `q_eff`, with up to `retries`
/// retries: Σ_{k=1..retries} qᵏ · (detect + backoff(k) + reblock),
/// where `backoff(k)` is the capped exponential `min(backoff0·2ᵏ⁻¹,
/// backoff_cap)`, `detect` is the strategy's failure-detection latency
/// and `reblock` the re-dispatched launch's source block.  The tail is
/// what the planner adds to a candidate's spawn block when a failure
/// probability is configured (`--faults` + `fail_p`): late-detecting
/// strategies (Async) buy their healthy-path overlap with a heavier
/// tail, which is exactly the trade the chaos sweep measures.
pub fn expected_spawn_retry_tail(
    q_eff: f64,
    retries: u32,
    detect: f64,
    backoff0: f64,
    backoff_cap: f64,
    reblock: f64,
) -> f64 {
    if q_eff <= 0.0 {
        return 0.0;
    }
    let q = q_eff.min(1.0);
    let mut tail = 0.0;
    let mut qk = 1.0;
    for k in 1..=retries.max(1) {
        qk *= q;
        let backoff = (backoff0 * f64::powi(2.0, k as i32 - 1)).min(backoff_cap);
        tail += qk * (detect + backoff + reblock);
    }
    tail
}

// ---------------------------------------------------------------------
// Reconfiguration-cost prediction (planner API)
// ---------------------------------------------------------------------

/// Inputs describing one `NS → ND` reconfiguration for
/// [`predict_reconfig`].  Everything is plain data so the planner
/// layer (`mam::planner`) can build a case from its registry without
/// this module depending on MaM types.
#[derive(Clone, Debug)]
pub struct ReconfigCase {
    pub ns: usize,
    pub nd: usize,
    /// Cores per node of the allocation (the paper's testbed: 20).
    pub cores_per_node: usize,
    /// Global bytes of each structure moved in the main redistribution
    /// phase (all entries for blocking strategies, the *constant*
    /// entries for background ones, §III).
    pub bulk_bytes: Vec<u64>,
    /// Global bytes of each structure moved in the blocking tail at
    /// `MAM_Finish` (the *variable* entries of background strategies;
    /// empty for blocking).
    pub tail_bytes: Vec<u64>,
    /// Window pool warm for the source exposures (a previous resize
    /// pinned the blocks; §VI register-on-receive).
    pub warm: bool,
    /// Persistent redistribution schedule warm for this `(NS, ND)`
    /// shape (a previous resize between the same sizes built and
    /// pinned it): replays charge only the validation handshake.
    pub sched_warm: bool,
    /// Application iteration time on the NS ranks (overlap modelling;
    /// 0 disables the overlap terms).
    pub t_iter_src: f64,
    /// Application iteration time on the ND ranks (overlap credits).
    pub t_iter_dst: f64,
    /// Seconds every source stays blocked in the spawn phase (0 for
    /// shrinks; [`SpawnSchedule::source_block`] for grows).
    pub spawn_block: f64,
    /// Seconds the spawn phase keeps running *after* the sources
    /// resume (`last_child_up − source_block`, clamped at 0; nonzero
    /// only for asynchronous spawning).  The redistribution's first
    /// collective cannot complete before the last spawned rank is up,
    /// so this gates the redistribution start — but one-sided
    /// registration is local and overlaps it (sources pin while the
    /// targets are still starting; with chunked registration the
    /// background streams ride this window too — the spawn-overlap
    /// term of the lifecycle pipeline).
    pub spawn_tail: f64,
    /// Per-wave start offsets of the spawned ranks *past the sources'
    /// release* (ascending, deduplicated; nonzero waves only under
    /// asynchronous spawning).  When present, the eager spawn-overlap
    /// registration stream is priced wave by wave — it runs through
    /// the inter-wave gaps and each wave's merge attach stalls it for
    /// one software handshake — instead of as a single tail gate.
    /// Empty = the legacy `max(registration, spawn_tail)` term, bit
    /// for bit.
    pub spawn_waves: Vec<f64>,
}

/// Structural knobs of one redistribution candidate — the shape of a
/// `(method × strategy × pool)` version, without naming MaM's enums.
#[derive(Clone, Copy, Debug)]
pub struct RedistShape {
    /// One-sided (RMA) reads instead of `MPI_Alltoallv`.
    pub one_sided: bool,
    /// One passive epoch per accessed target (RMA-Lock, Alg. 2) rather
    /// than a single `lock_all` epoch (RMA-Lockall, Alg. 3).
    pub lock_per_target: bool,
    /// Background strategy (NB / WD): completion is detected once per
    /// application iteration and variable data moves in a blocking
    /// tail.
    pub background: bool,
    /// Auxiliary-thread strategy (§V-D): MT progress penalties apply.
    pub threading: bool,
    /// Persistent window pool (§VI): warm acquires skip registration,
    /// releases skip deregistration, received blocks are re-pinned.
    pub pool: bool,
    /// Chunked pipelined registration (`--rma-chunk`): segment size in
    /// bytes.  0 = unchunked; ignored for two-sided candidates.  Cold
    /// registration then splits into a *fill* (first segment, on the
    /// collective critical path) and a background stream overlapped
    /// with the wire — only the stream's excess over the wire time (the
    /// pipeline drain) stays serial.
    pub chunk_bytes: u64,
    /// Notified completion (`--rma-sync notify`): per-op notification
    /// flags replace the passive epochs and teardown is local —
    /// windows close on per-segment notify counts, without the
    /// collective sync round or the confirmation barrier.
    pub notify_sync: bool,
    /// Persistent-schedule cache (`--sched-cache on`): charge the cold
    /// schedule build (or, warm, only the validation handshake) per
    /// structure.  Off charges nothing — the seed recompute path.
    pub sched_cache: bool,
}

/// Decomposed cost prediction of one reconfiguration candidate.
///
/// `reconf_time` estimates the full reconfiguration span (spawn +
/// redistribution + blocking tail); `effective` subtracts the overlap
/// credit — iterations of post-resize work a background strategy
/// completes while the redistribution is in flight (the Eq. (2)
/// accounting of §V-C).
#[derive(Clone, Copy, Debug, Default)]
pub struct CostPrediction {
    /// Source-blocked spawn phase (grow only).
    pub spawn: f64,
    /// Window registration on the collective critical path (RMA only).
    pub registration: f64,
    /// Bulk serialization time at the bottleneck NIC.
    pub wire: f64,
    /// Per-message software: epochs + Get initiation (RMA) or
    /// pack/handshake (COL), plus collective synchronization rounds.
    pub protocol: f64,
    /// Window teardown (deregistration, or pooled release + the
    /// register-on-receive pre-pins of §VI).
    pub teardown: f64,
    /// Blocking variable-data tail of background strategies.
    pub tail: f64,
    /// Redistribution span estimate (everything but spawn and tail).
    pub redist: f64,
    /// Predicted reconfiguration span: spawn + redist + tail.
    pub reconf_time: f64,
    /// Iterations the application overlaps with a background
    /// redistribution (0 for blocking).
    pub overlap_iters: f64,
    /// Post-resize work completed during the overlap
    /// (`overlap_iters × t_iter_dst`).
    pub overlap_credit: f64,
    /// `reconf_time − overlap_credit` — the Eq. (2)-style objective.
    pub effective: f64,
}

/// Block `[ini, end)` of rank `r` in an `n`-way distribution of
/// `total` bytes — mirrors MaM's block scheme (remainder spread over
/// the first ranks), so predicted exposure/receive sizes match the
/// simulated ones exactly.
fn pred_block(total: u64, n: usize, r: usize) -> (u64, u64) {
    let n64 = n as u64;
    let base = total / n64;
    let rem = total % n64;
    let r64 = r as u64;
    let ini = r64 * base + r64.min(rem);
    (ini, ini + base + u64::from(r64 < rem))
}

/// Bytes that change ranks when `total` bytes move from an `ns`-way to
/// an `nd`-way block distribution (rank `d`'s overlap with its own old
/// block stays put).
pub fn moved_bytes(total: u64, ns: usize, nd: usize) -> u64 {
    let mut moved = 0u64;
    for d in 0..nd {
        let (ini, end) = pred_block(total, nd, d);
        let keep = if d < ns {
            let (si, se) = pred_block(total, ns, d);
            end.min(se).saturating_sub(ini.max(si))
        } else {
            0
        };
        moved += (end - ini) - keep;
    }
    moved
}

/// Sensitivity of a redistribution span to `beta_inter`:
/// `d(span)/dβ ≈` the serialized inter-node byte count at the busiest
/// NIC.  Ranks are mapped to `⌈max(ns,nd)/cpn⌉` nodes cyclically
/// (`Topology::new_cyclic`'s scheme, rank → rank mod nodes); every
/// cross-node overlap byte is charged to both endpoints' NICs and the
/// maximum in+out total over nodes is returned.  Used by the online
/// recalibrator ([`crate::mam::recalib`]) as the slope of its
/// trust-region Newton update — a ≤ ~2× slope error only slows, never
/// breaks, its geometric convergence.  Returns 0 for single-node
/// shapes (the wire is intra-node there).
pub fn wire_slope(total: u64, ns: usize, nd: usize, cores_per_node: usize) -> f64 {
    let n = ns.max(nd).max(1);
    let nodes = n.div_ceil(cores_per_node.max(1)).max(1);
    if nodes <= 1 {
        return 0.0;
    }
    let mut traffic = vec![0u64; nodes];
    for s in 0..ns {
        let (si, se) = pred_block(total, ns, s);
        for d in 0..nd {
            if s == d {
                continue; // the overlap with its own old block stays put
            }
            let (di, de) = pred_block(total, nd, d);
            let ov = se.min(de).saturating_sub(si.max(di));
            if ov == 0 {
                continue;
            }
            let (sn, dn) = (s % nodes, d % nodes);
            if sn != dn {
                traffic[sn] += ov;
                traffic[dn] += ov;
            }
        }
    }
    traffic.into_iter().max().unwrap_or(0) as f64
}

/// Predict the cost of one reconfiguration candidate.
///
/// The prediction mirrors the structure of the simulated cost model:
/// the *shared* terms (bulk wire time at the bottleneck NIC) are the
/// same for every candidate, while the *differential* terms — window
/// registration and teardown versus pack/handshake, epochs, pool
/// pre-pins, MT penalties, overlap quantization — are computed from
/// the same calibrated constants the simulator charges.  Rankings
/// between candidates therefore track the simulator even where the
/// absolute numbers drift; `mam::planner` refines the close calls with
/// exact DES micro-probes.
pub fn predict_reconfig(p: &NetParams, c: &ReconfigCase, s: &RedistShape) -> CostPrediction {
    assert!(c.ns > 0 && c.nd > 0, "degenerate reconfiguration");
    let n = c.ns.max(c.nd);
    let nodes = n.div_ceil(c.cores_per_node.max(1)).max(1);
    let (alpha, beta) = if nodes == 1 {
        (p.alpha_intra, p.beta_intra)
    } else {
        (p.alpha_inter, p.beta_inter)
    };
    // Sources a drain intersects under the block scheme (Algorithm 1).
    let accessed = (c.ns.div_ceil(c.nd) + 1).clamp(1, c.ns);
    let k = c.bulk_bytes.len() as f64;
    // Bulk wire time: the bottleneck NIC serializes its share of the
    // moved bytes (cyclic placement spreads both groups over all
    // allocated nodes, §V-A).
    let moved: u64 = c.bulk_bytes.iter().map(|&b| moved_bytes(b, c.ns, c.nd)).sum();
    let mut wire = alpha + moved as f64 / nodes as f64 * beta;
    // One synchronization (dissemination rounds of small messages) per
    // collective call.
    let rounds = (usize::BITS - (n - 1).leading_zeros()) as f64;
    let sync = rounds * (alpha + 16.0 * beta);
    let (mut registration, mut protocol, teardown) = if s.one_sided {
        let mut registration = 0.0;
        let mut teardown = 0.0;
        // Chunked pipelining: background-registered (and, on teardown,
        // background-deregistered) bytes accumulate per source rank —
        // each rank's stream runs on its own engine, so the bottleneck
        // rank (the largest exposure) is what rides against the wire
        // after the loop.  Pricing the fill per rank rather than from
        // rank 0 alone keeps uneven shapes honest: the collective gate
        // is the true per-rank maximum.
        let chunk = s.chunk_bytes as f64;
        // Notified teardown is local: windows close once the per-segment
        // notify counts match, without the collective sync round or the
        // confirmation barrier.  (Window *creation* stays collective.)
        let tear_sync = if s.notify_sync { 0.0 } else { sync };
        let mut rest_by_rank = vec![0.0f64; c.ns];
        let mut dereg_by_rank = vec![0.0f64; c.ns];
        let mut extra_get_ops = 0.0;
        for &b in &c.bulk_bytes {
            let (d0, de) = pred_block(b, c.nd, 0);
            let recv = (de - d0) as f64;
            let warm = s.pool && c.warm;
            // Win_create: everyone pins in parallel after arriving; the
            // slowest rank's fill gates the collective exit.
            let mut fill_max = 0.0f64;
            // Serial per-byte dereg of ranks the chunking leaves
            // unsegmented (their exposure fits one segment).
            let mut serial_dereg_max = 0.0f64;
            for r in 0..c.ns {
                let (i0, e0) = pred_block(b, c.ns, r);
                let src = (e0 - i0) as f64;
                let fill = if warm {
                    p.win_setup
                } else if chunk > 0.0 && src > chunk {
                    // Fill: setup + the first segment only; the rest of
                    // the exposure registers in the background (one
                    // extra setup per later segment).
                    let n_seg = (src / chunk).ceil();
                    rest_by_rank[r] +=
                        (n_seg - 1.0) * p.win_setup + (src - chunk) * p.beta_register;
                    p.win_setup + chunk * p.beta_register
                } else {
                    p.win_setup + src * p.beta_register
                };
                fill_max = fill_max.max(fill);
                if !s.pool {
                    if chunk > 0.0 && src > chunk {
                        // Pipelined teardown: this rank's per-byte
                        // dereg rides the wire as a background stream.
                        dereg_by_rank[r] += src * p.beta_register / 3.0;
                    } else {
                        serial_dereg_max = serial_dereg_max.max(src * p.beta_register / 3.0);
                    }
                }
            }
            registration += sync + fill_max;
            if chunk > 0.0 && recv > chunk {
                // One Get per touched segment instead of one per source.
                extra_get_ops += ((recv / chunk).ceil() - accessed as f64).max(0.0);
            }
            teardown += tear_sync
                + if s.pool {
                    // Release keeps memory pinned; drains then pre-pin
                    // the received block (register-on-receive, §VI) —
                    // cold only, and an investment that makes the next
                    // resize warm.
                    p.win_setup * 0.5
                        + if c.warm { 0.0 } else { p.win_setup + recv * p.beta_register }
                } else {
                    p.win_setup * 0.5 + serial_dereg_max
                };
        }
        let rest_max = rest_by_rank.iter().fold(0.0f64, |a, &b| a.max(b));
        if rest_max > 0.0 {
            // Pipeline drain: the bottleneck rank's background stream
            // runs concurrently with the wire (and, under asynchronous
            // spawning, with the spawn tail — the eager streams start
            // at each rank's own fill) — only its excess stays serial.
            let overlap = wire + if chunk > 0.0 { c.spawn_tail } else { 0.0 };
            registration += (rest_max - overlap).max(0.0);
        }
        let dereg_max = dereg_by_rank.iter().fold(0.0f64, |a, &b| a.max(b));
        if dereg_max > 0.0 {
            // The dereg streams ride whatever wire the registration
            // streams left uncovered; the rest is the teardown residual
            // (the last segments' unpin after the final reads land).
            let slack = (wire - rest_max).max(0.0);
            teardown += (dereg_max - slack).max(0.0);
        }
        let sync_sw = if s.notify_sync {
            // Notified completion: one flag per posted read plus the
            // arm of the expected count — no passive epochs at all.
            p.notify_overhead * (accessed as f64 + 1.0)
        } else if s.lock_per_target {
            2.0 * p.epoch_cost * accessed as f64
        } else {
            4.0 * p.epoch_cost
        };
        let extra_op = p.op_overhead
            + p.get_overhead
            + if s.notify_sync { p.notify_overhead } else { 0.0 };
        let mut protocol = k * (sync_sw + (p.op_overhead + p.get_overhead) * accessed as f64)
            + extra_get_ops * extra_op;
        if s.sched_cache {
            // Persistent redistribution schedules: the cold build pays
            // the planning (targets, read lists, segment layout, sync
            // plan) once per structure; warm replays charge only the
            // validation handshake.
            protocol += k * if c.sched_warm {
                p.sched_validate
            } else {
                p.sched_build + p.sched_per_target * 2.0 * accessed as f64
            };
        }
        (registration, protocol, teardown)
    } else {
        // Two-sided: per-message pack CPU (bounded by the eager
        // threshold), the rendezvous handshake of bulk messages, one
        // alltoallv synchronization per structure.
        let msg = moved as f64 / (c.nd.max(1) * accessed) as f64;
        let pack = msg.min(p.eager_threshold as f64) * p.beta_memcpy;
        let protocol = k * (accessed as f64 * (p.op_overhead + pack) + p.rendezvous_rtt + sync);
        let mut teardown = 0.0;
        if s.pool {
            // COL creates no windows, but register-on-receive still
            // pins the received blocks inside the span when the pool
            // is enabled (warming later RMA resizes).  Priced per
            // drain rank; the bottleneck (largest block) is the term.
            for &b in &c.bulk_bytes {
                let mut pin_max = 0.0f64;
                for r in 0..c.nd {
                    let (d0, de) = pred_block(b, c.nd, r);
                    pin_max = pin_max
                        .max(p.win_setup + (de - d0) as f64 * p.beta_register);
                }
                teardown += if c.warm { 0.0 } else { pin_max };
            }
        }
        (0.0, protocol, teardown)
    };
    // Asynchronous spawning leaves the spawn phase running past the
    // sources' release: the redistribution's first collective cannot
    // complete before the last spawned rank is up.  One-sided
    // registration is local and overlaps the tail (the gate is
    // whichever is longer); two-sided candidates simply wait it out.
    if c.spawn_tail > 0.0 {
        if s.one_sided {
            if c.spawn_waves.is_empty() {
                registration = registration.max(c.spawn_tail);
            } else {
                // Per-wave pricing of the eager spawn-overlap stream:
                // registration work runs through the inter-wave gaps,
                // and each wave's merge attach stalls the stream for
                // one software handshake.  The collective still gates
                // on the last wave; only the stream seconds the gaps
                // absorbed come off the serial registration term.
                let mut t = 0.0f64; // clock past the sources' release
                let mut run = 0.0f64; // stream seconds already executed
                for &w in &c.spawn_waves {
                    run += (w - t).max(0.0);
                    t = t.max(w) + p.op_overhead;
                }
                t = t.max(c.spawn_tail);
                registration = t + (registration - run).max(0.0);
            }
        } else {
            protocol += c.spawn_tail;
        }
    }
    if s.threading {
        // §V-D: MT passive-target progress is the worst MPICH path for
        // RMA; collectives crawl under the contended global lock.
        wire *= if s.one_sided { p.mt_rma_penalty } else { p.mt_coll_penalty };
        protocol *= p.mt_coll_penalty;
    }
    let tail_moved: u64 = c.tail_bytes.iter().map(|&b| moved_bytes(b, c.ns, c.nd)).sum();
    let tail = if c.tail_bytes.is_empty() {
        0.0
    } else {
        alpha + tail_moved as f64 / nodes as f64 * beta + sync
    };
    let base_span = registration + wire + protocol + teardown;
    // Background completion is polled once per application iteration:
    // the span is quantized up by one (possibly slowed) iteration, and
    // every overlapped iteration is post-resize work already done.
    let (quantization, overlap_iters) = if s.background && c.t_iter_src > 0.0 {
        let omega = if s.threading {
            p.oversub_factor
        } else {
            1.0 + (p.small_lane_max_wait / c.t_iter_src).min(1.8)
        };
        let t_bg = c.t_iter_src * omega;
        (t_bg, ((base_span + t_bg) / t_bg).ceil())
    } else {
        (0.0, 0.0)
    };
    let overlap_credit = overlap_iters * c.t_iter_dst;
    let redist = base_span + quantization;
    let reconf_time = c.spawn_block + redist + tail;
    CostPrediction {
        spawn: c.spawn_block,
        registration,
        wire,
        protocol,
        teardown,
        tail,
        redist,
        reconf_time,
        overlap_iters,
        overlap_credit,
        effective: reconf_time - overlap_credit,
    }
}

/// Mutable cost model: parameters + NIC occupancy state.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub params: NetParams,
    /// Per-node bulk-lane busy-until time.
    nic_busy: Vec<Time>,
}

impl CostModel {
    pub fn new(params: NetParams, n_nodes: usize) -> CostModel {
        CostModel { params, nic_busy: vec![0.0; n_nodes] }
    }

    /// Reset NIC occupancy (between experiment repetitions).
    pub fn reset(&mut self) {
        self.nic_busy.iter_mut().for_each(|t| *t = 0.0);
    }

    /// Pure memcpy time for `bytes` (local copies, self-messages).
    pub fn memcpy_time(&self, bytes: u64) -> f64 {
        bytes as f64 * self.params.beta_memcpy
    }

    /// Window creation cost for one rank exposing `bytes`
    /// (ibv_reg_mr pinning + window setup); local, per §IV-B one window
    /// per data structure.
    pub fn window_registration(&self, bytes: u64) -> f64 {
        self.params.win_setup + bytes as f64 * self.params.beta_register
    }

    /// Window free cost (deregistration is ~3x faster than pinning).
    pub fn window_free(&self, bytes: u64) -> f64 {
        self.params.win_setup * 0.5 + bytes as f64 * self.params.beta_register / 3.0
    }

    /// Pooled-window acquire cost (§VI window pool).  A *cold* acquire
    /// is a full `Win_create`: fixed setup plus `ibv_reg_mr` pinning of
    /// every exposed byte.  A *warm* acquire re-exposes memory that is
    /// still registered with the NIC: only the fixed setup (rkey
    /// exchange, window object) is charged — the per-byte registration,
    /// the paper's dominant RMA overhead, vanishes.
    pub fn window_acquire(&self, bytes: u64, warm: bool) -> f64 {
        if warm {
            self.params.win_setup
        } else {
            self.window_registration(bytes)
        }
    }

    /// Pooled-window release cost: the window object returns to the
    /// pool with its memory still pinned, so unlike
    /// [`CostModel::window_free`] there is no per-byte deregistration.
    pub fn window_release(&self) -> f64 {
        self.params.win_setup * 0.5
    }

    /// Route one message; updates NIC occupancy.  `now` is the moment
    /// the initiator posts the operation.
    pub fn transfer(
        &mut self,
        now: Time,
        placement: &Placement,
        src_rank: usize,
        dst_rank: usize,
        bytes: u64,
        class: TransferClass,
    ) -> TransferTiming {
        let p = &self.params;
        // CPU charge at the initiator.
        let cpu = match class {
            TransferClass::TwoSided => {
                p.op_overhead + bytes.min(p.eager_threshold) as f64 * p.beta_memcpy
            }
            TransferClass::Rma => p.op_overhead + p.get_overhead,
        };
        let cpu_done = now + cpu;

        if src_rank == dst_rank {
            // Self-message: one memcpy.
            let t = now + p.op_overhead + self.memcpy_time(bytes);
            return TransferTiming { cpu_done: t, arrival: t };
        }

        if placement.same_node(src_rank, dst_rank) {
            // Shared-memory path; no NIC involvement.
            let mut dur = p.alpha_intra + bytes as f64 * p.beta_intra;
            if bytes > p.eager_threshold {
                dur += p.rendezvous_rtt * 0.25; // cheap local handshake
            }
            return TransferTiming { cpu_done, arrival: now + dur };
        }

        let src_node = placement.node_of(src_rank).0;
        let dst_node = placement.node_of(dst_rank).0;
        if bytes >= p.eager_threshold {
            // Bulk lane: each endpoint NIC serializes *its own* bytes
            // (store-and-forward through the switch: the egress NIC may
            // stream into fabric buffers before the ingress NIC drains
            // them).  The message has fully arrived when the later of
            // the two NICs finishes its serialization.  Charging wire
            // time per-NIC — instead of blocking both NICs for the
            // common interval — keeps aggregate per-node throughput at
            // the link rate, which is what an IB EDR fat-tree delivers
            // for the all-to-all-style traffic of a redistribution.
            let hand = if class == TransferClass::TwoSided { p.rendezvous_rtt } else { 0.0 };
            let wire = bytes as f64 * p.beta_inter;
            let src_done = now.max(self.nic_busy[src_node]) + wire;
            self.nic_busy[src_node] = src_done;
            let dst_done = now.max(self.nic_busy[dst_node]) + wire;
            self.nic_busy[dst_node] = dst_done;
            let end = hand + p.alpha_inter + src_done.max(dst_done);
            TransferTiming { cpu_done, arrival: end }
        } else {
            // Small lane: bounded queueing behind bulk backlog.
            let backlog = (self.nic_busy[src_node] - now)
                .max(self.nic_busy[dst_node] - now)
                .max(0.0)
                .min(p.small_lane_max_wait);
            let arrival = now + backlog + p.alpha_inter + bytes as f64 * p.beta_inter;
            TransferTiming { cpu_done, arrival }
        }
    }

    /// Current bulk backlog of the NIC serving `rank` (diagnostics).
    pub fn nic_backlog(&self, placement: &Placement, rank: usize, now: Time) -> f64 {
        (self.nic_busy[placement.node_of(rank).0] - now).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netmodel::topology::Topology;

    fn setup() -> (CostModel, Placement) {
        let topo = Topology::new(4, 4);
        let placement = Placement::block(&topo, 16);
        (CostModel::new(NetParams::test_simple(), 4), placement)
    }

    #[test]
    fn retry_tail_is_zero_when_healthy_and_grows_with_q_and_detection() {
        assert_eq!(expected_spawn_retry_tail(0.0, 3, 0.1, 0.02, 0.16, 0.05), 0.0);
        let low = expected_spawn_retry_tail(0.1, 2, 0.1, 0.02, 0.16, 0.05);
        let high = expected_spawn_retry_tail(0.5, 2, 0.1, 0.02, 0.16, 0.05);
        assert!(low > 0.0 && high > low, "tail must grow with q: {low} vs {high}");
        // Late detection (Async-style) costs more than early detection.
        let late = expected_spawn_retry_tail(0.5, 2, 0.4, 0.02, 0.16, 0.05);
        assert!(late > high);
        // One exact term: q=1, one retry, capped backoff.
        let t = expected_spawn_retry_tail(1.0, 1, 0.1, 0.5, 0.2, 0.05);
        assert!((t - (0.1 + 0.2 + 0.05)).abs() < 1e-12, "{t}");
    }

    #[test]
    fn self_message_is_memcpy() {
        let (mut cm, pl) = setup();
        let t = cm.transfer(0.0, &pl, 3, 3, 1000, TransferClass::TwoSided);
        let expect = 1e-6 + 1000.0 * 1e-10;
        assert!((t.arrival - expect).abs() < 1e-15);
    }

    #[test]
    fn intra_node_uses_shm_constants() {
        let (mut cm, pl) = setup();
        // ranks 0 and 1 are on node 0
        let t = cm.transfer(0.0, &pl, 0, 1, 512, TransferClass::TwoSided);
        let expect = 1e-4 + 512.0 * 1e-10;
        assert!((t.arrival - expect).abs() < 1e-12, "{}", t.arrival);
    }

    #[test]
    fn inter_node_small_message() {
        let (mut cm, pl) = setup();
        // ranks 0 (node 0) → 5 (node 1), small message, idle NICs.
        let t = cm.transfer(0.0, &pl, 0, 5, 100, TransferClass::TwoSided);
        let expect = 1e-3 + 100.0 * 1e-9;
        assert!((t.arrival - expect).abs() < 1e-12);
    }

    #[test]
    fn bulk_transfers_serialize_on_nic() {
        let (mut cm, pl) = setup();
        let mb = 1_000_000u64;
        // Two bulk transfers out of node 0 posted at the same instant.
        let t1 = cm.transfer(0.0, &pl, 0, 5, mb, TransferClass::Rma);
        let t2 = cm.transfer(0.0, &pl, 1, 9, mb, TransferClass::Rma);
        let wire = mb as f64 * 1e-9;
        assert!((t1.arrival - (1e-3 + wire)).abs() < 1e-9);
        // Second starts after the first releases node-0's NIC.
        assert!(t2.arrival >= t1.arrival + wire - 1e-9, "{} {}", t2.arrival, t1.arrival);
    }

    #[test]
    fn disjoint_node_pairs_do_not_contend() {
        let (mut cm, pl) = setup();
        let mb = 1_000_000u64;
        let t1 = cm.transfer(0.0, &pl, 0, 5, mb, TransferClass::Rma); // 0→1
        let t2 = cm.transfer(0.0, &pl, 8, 13, mb, TransferClass::Rma); // 2→3
        assert!((t1.arrival - t2.arrival).abs() < 1e-12);
    }

    #[test]
    fn small_lane_wait_is_bounded() {
        let (mut cm, pl) = setup();
        // Saturate node 0's NIC with a huge bulk transfer.
        cm.transfer(0.0, &pl, 0, 5, 1_000_000_000, TransferClass::Rma);
        // A small message still gets through within the lane bound.
        let t = cm.transfer(0.0, &pl, 1, 6, 64, TransferClass::TwoSided);
        let max_expected = 1e-3 /*cap*/ + 1e-3 /*alpha*/ + 64.0 * 1e-9 + 1e-9;
        assert!(t.arrival <= max_expected, "{}", t.arrival);
    }

    #[test]
    fn rma_cpu_charge_is_size_independent() {
        // One-sided reads are hardware-offloaded: the origin pays a
        // constant software cost regardless of transfer size, while the
        // wire time still scales.
        let (mut cm, pl) = setup();
        let small = cm.transfer(0.0, &pl, 0, 5, 1_000, TransferClass::Rma);
        let mut cm2 = CostModel::new(NetParams::test_simple(), 4);
        let big = cm2.transfer(0.0, &pl, 0, 5, 100_000_000, TransferClass::Rma);
        assert!((small.cpu_done - big.cpu_done).abs() < 1e-12);
        assert!(big.arrival > small.arrival * 10.0);
    }

    #[test]
    fn rendezvous_adds_handshake() {
        let (mut cm, pl) = setup();
        let small = cm.transfer(0.0, &pl, 0, 5, 1023, TransferClass::TwoSided).arrival;
        let mut cm2 = CostModel::new(NetParams::test_simple(), 4);
        let big = cm2.transfer(0.0, &pl, 0, 5, 1025, TransferClass::TwoSided).arrival;
        // 2 extra bytes of wire time cannot explain the gap: handshake.
        assert!(big - small > 1.9e-3, "gap={}", big - small);
    }

    #[test]
    fn registration_scales_with_bytes() {
        let (cm, _) = setup();
        let r1 = cm.window_registration(0);
        let r2 = cm.window_registration(1_000_000_000);
        assert!((r1 - 1e-4).abs() < 1e-12);
        assert!((r2 - (1e-4 + 1.0)).abs() < 1e-9);
        assert!(cm.window_free(1_000_000_000) < r2);
    }

    #[test]
    fn warm_acquire_skips_registration() {
        let (cm, _) = setup();
        let bytes = 1_000_000_000u64;
        let cold = cm.window_acquire(bytes, false);
        let warm = cm.window_acquire(bytes, true);
        // Cold == the seed's full Win_create registration charge.
        assert_eq!(cold.to_bits(), cm.window_registration(bytes).to_bits());
        // Warm charges the fixed setup only: no per-byte term at all.
        assert_eq!(warm.to_bits(), cm.window_acquire(1, true).to_bits());
        assert!(warm < cold);
        // Release keeps memory pinned: cheaper than a full free.
        assert!(cm.window_release() < cm.window_free(bytes));
    }

    #[test]
    fn atomic_spawn_schedule_is_one_constant() {
        let s = SpawnSchedule::atomic(0.25);
        assert_eq!(s.initiate.to_bits(), 0.25f64.to_bits());
        assert_eq!(s.source_block.to_bits(), 0.25f64.to_bits());
        assert!(s.child_up.is_empty());
        assert_eq!(s.last_child_up(), 0.0);
    }

    #[test]
    fn parallel_spawn_staggers_by_wave_and_blocks_through_merge() {
        let p = NetParams::test_simple();
        // 2 roots spawning 5 targets: waves of 2 → waves ⌈5/2⌉ = 3.
        let s = SpawnSchedule::parallel(&p, 2, 5, 7);
        assert_eq!(s.child_up.len(), 5);
        // Round-robin waves: children 0,1 in wave 0; 2,3 wave 1; 4 wave 2.
        assert_eq!(s.child_up[0], s.child_up[1]);
        assert!(s.child_up[2] > s.child_up[1]);
        assert_eq!(s.child_up[2], s.child_up[3]);
        assert!(s.child_up[4] > s.child_up[3]);
        // Sources resume only after the last wave + merge.
        let merge = intercomm_merge_cost(&p, 7);
        assert!(merge > 0.0);
        assert!((s.source_block - (s.last_child_up() + merge)).abs() < 1e-15);
        assert!((s.initiate - p.spawn_launch).abs() < 1e-15);
    }

    #[test]
    fn async_spawn_unblocks_sources_at_launch() {
        let p = NetParams::test_simple();
        let s = SpawnSchedule::asynchronous(&p, 4, 8, 12);
        assert_eq!(s.initiate.to_bits(), s.source_block.to_bits());
        assert!((s.initiate - p.spawn_launch).abs() < 1e-15);
        // Targets carry the merge cost themselves and come up after the
        // sources resumed.
        assert!(s.child_up.iter().all(|&u| u > s.source_block));
        // Same wave structure as Parallel, shifted by the merge.
        let par = SpawnSchedule::parallel(&p, 4, 8, 12);
        let merge = intercomm_merge_cost(&p, 12);
        for (a, b) in s.child_up.iter().zip(&par.child_up) {
            assert!((a - (b + merge)).abs() < 1e-15);
        }
    }

    #[test]
    fn merge_cost_grows_logarithmically() {
        let p = NetParams::test_simple();
        assert_eq!(intercomm_merge_cost(&p, 2), p.merge_round);
        assert_eq!(intercomm_merge_cost(&p, 16), 4.0 * p.merge_round);
        assert_eq!(intercomm_merge_cost(&p, 17), 5.0 * p.merge_round);
        // Degenerate sizes clamp to one round.
        assert_eq!(intercomm_merge_cost(&p, 1), p.merge_round);
    }

    fn case(ns: usize, nd: usize) -> ReconfigCase {
        ReconfigCase {
            ns,
            nd,
            cores_per_node: 20,
            bulk_bytes: vec![640_000_000, 320_000_000, 8_000_000],
            tail_bytes: Vec::new(),
            warm: false,
            sched_warm: false,
            t_iter_src: 0.05,
            t_iter_dst: 0.02,
            spawn_block: 0.0,
            spawn_tail: 0.0,
            spawn_waves: Vec::new(),
        }
    }

    fn shape(one_sided: bool) -> RedistShape {
        RedistShape {
            one_sided,
            lock_per_target: false,
            background: false,
            threading: false,
            pool: false,
            chunk_bytes: 0,
            notify_sync: false,
            sched_cache: false,
        }
    }

    #[test]
    fn pred_block_matches_mam_block_of() {
        // The predictor re-derives MaM's block scheme so the planner's
        // exposure/receive sizes match the simulated ones exactly; this
        // sweep pins the two implementations together.
        for total in [0u64, 1, 7, 97, 1_000, 72_067_110] {
            for n in [1usize, 2, 3, 7, 20, 160] {
                for r in 0..n {
                    let (ini, end) = pred_block(total, n, r);
                    let b = crate::mam::block_of(total, n, r);
                    assert_eq!((ini, end), (b.ini, b.end), "total={total} n={n} r={r}");
                }
            }
        }
    }

    #[test]
    fn moved_bytes_counts_only_cross_rank_traffic() {
        // Same size: nothing moves.  NS ≠ ND: everything outside the
        // per-rank overlap moves, bounded by the total.
        assert_eq!(moved_bytes(1000, 4, 4), 0);
        let m = moved_bytes(1000, 2, 4);
        assert!(m > 0 && m <= 1000, "moved={m}");
        // Doubling the data doubles the traffic.
        assert_eq!(moved_bytes(2000, 2, 4), 2 * m);
    }

    #[test]
    fn wire_slope_tracks_cross_node_traffic() {
        // Single node: β_inter is never exercised.
        assert_eq!(wire_slope(1 << 20, 2, 4, 8), 0.0);
        // Multi-node grows: positive, bounded by twice the moved bytes
        // (each byte hits at most two NICs), and linear in the total.
        let s = wire_slope(1 << 20, 4, 16, 8);
        assert!(s > 0.0, "s={s}");
        assert!(s <= 2.0 * moved_bytes(1 << 20, 4, 16) as f64);
        let s2 = wire_slope(1 << 21, 4, 16, 8);
        assert!((s2 - 2.0 * s).abs() < 1e-9, "s2={s2} s={s}");
        // A same-shape resize moves nothing.
        assert_eq!(wire_slope(1 << 20, 8, 8, 4), 0.0);
    }

    #[test]
    fn prediction_is_finite_positive_and_decomposes() {
        let p = NetParams::sarteco25();
        for (ns, nd) in [(20, 160), (160, 20), (40, 80), (160, 40)] {
            for one_sided in [false, true] {
                let pr = predict_reconfig(&p, &case(ns, nd), &shape(one_sided));
                assert!(pr.reconf_time.is_finite() && pr.reconf_time > 0.0, "{pr:?}");
                assert!(pr.redist > 0.0 && pr.wire > 0.0, "{pr:?}");
                assert!(pr.effective <= pr.reconf_time + 1e-15, "{pr:?}");
                let sum = pr.registration + pr.wire + pr.protocol + pr.teardown;
                assert!((pr.redist - sum).abs() < 1e-12, "blocking redist must decompose");
            }
        }
    }

    #[test]
    fn chunked_prediction_hides_registration_behind_the_wire() {
        let p = NetParams::sarteco25();
        let blocking = predict_reconfig(&p, &case(20, 160), &shape(true));
        let mut s = shape(true);
        s.chunk_bytes = 1 << 20;
        let piped = predict_reconfig(&p, &case(20, 160), &s);
        // Cold grow from 20 sources: registration is substantial and
        // the wire covers the background stream — the chunked span
        // must drop by (almost) the whole serial registration term.
        assert!(
            piped.registration < 0.15 * blocking.registration,
            "fill too large: {} vs {}",
            piped.registration,
            blocking.registration
        );
        assert!(piped.reconf_time < blocking.reconf_time, "{piped:?} vs {blocking:?}");
        // The wire itself is untouched; the extra per-segment Gets only
        // nudge the protocol term.
        assert_eq!(piped.wire.to_bits(), blocking.wire.to_bits());
        assert!(piped.protocol >= blocking.protocol);
    }

    #[test]
    fn chunked_prediction_with_zero_chunk_is_bit_identical() {
        let p = NetParams::sarteco25();
        for one_sided in [false, true] {
            let a = predict_reconfig(&p, &case(160, 20), &shape(one_sided));
            let mut s = shape(one_sided);
            s.chunk_bytes = 0;
            let b = predict_reconfig(&p, &case(160, 20), &s);
            assert_eq!(a.reconf_time.to_bits(), b.reconf_time.to_bits());
            assert_eq!(a.registration.to_bits(), b.registration.to_bits());
            assert_eq!(a.protocol.to_bits(), b.protocol.to_bits());
        }
    }

    #[test]
    fn warm_chunked_prediction_equals_warm_unchunked_registration() {
        // All segments warm: the pipeline collapses — registration is
        // the fixed setup either way.
        let p = NetParams::sarteco25();
        let mut c = case(20, 160);
        c.warm = true;
        let mut plain = shape(true);
        plain.pool = true;
        let mut chunked = plain;
        chunked.chunk_bytes = 1 << 20;
        let a = predict_reconfig(&p, &c, &plain);
        let b = predict_reconfig(&p, &c, &chunked);
        assert_eq!(a.registration.to_bits(), b.registration.to_bits());
    }

    #[test]
    fn tiny_chunks_pay_their_setup_overhead() {
        // The chunk-size tradeoff the ablation sweeps: absurdly small
        // segments mean many per-segment setups — if the background
        // stream outgrows the wire, the drain term shows up again.
        let p = NetParams::sarteco25();
        let mut small = shape(true);
        small.chunk_bytes = 4 << 10; // 4 KiB: ~790k segments for 3.2 GB
        let mut big = shape(true);
        big.chunk_bytes = 16 << 20;
        let a = predict_reconfig(&p, &case(20, 160), &small);
        let b = predict_reconfig(&p, &case(20, 160), &big);
        assert!(
            a.reconf_time > b.reconf_time,
            "4 KiB chunks should lose to 16 MiB: {} vs {}",
            a.reconf_time,
            b.reconf_time
        );
    }

    #[test]
    fn chunked_prediction_pipelines_the_teardown_too() {
        // Cold one-sided with large per-source exposures: the chunked
        // shape's dereg streams ride the wire, so its teardown term
        // must drop well below the unchunked serial dereg — down to
        // the fixed per-window costs plus any residual.
        let p = NetParams::sarteco25();
        let blocking = predict_reconfig(&p, &case(20, 160), &shape(true));
        let mut s = shape(true);
        s.chunk_bytes = 4 << 20;
        let piped = predict_reconfig(&p, &case(20, 160), &s);
        assert!(
            piped.teardown < 0.5 * blocking.teardown,
            "teardown not pipelined: {} vs {}",
            piped.teardown,
            blocking.teardown
        );
        // The wire is untouched either way.
        assert_eq!(piped.wire.to_bits(), blocking.wire.to_bits());
    }

    #[test]
    fn per_rank_fill_pricing_matches_the_rank0_bottleneck_on_block_shapes() {
        // Under the block scheme rank 0 always carries the largest
        // exposure, so the per-rank maximum must coincide with the
        // historical rank-0 pricing on even and uneven shapes alike —
        // while staying finite/positive on degenerate ones (more
        // sources than elements: some ranks expose nothing).
        let p = NetParams::sarteco25();
        for (ns, nd) in [(3usize, 7usize), (7, 3), (160, 20)] {
            let mut c = case(ns, nd);
            c.bulk_bytes = vec![1_000_003, 64];
            for chunk in [0u64, 4 << 10] {
                let mut s = shape(true);
                s.chunk_bytes = chunk;
                let pr = predict_reconfig(&p, &c, &s);
                assert!(pr.registration.is_finite() && pr.registration > 0.0, "{pr:?}");
                assert!(pr.teardown.is_finite() && pr.teardown > 0.0, "{pr:?}");
            }
        }
    }

    #[test]
    fn spawn_tail_gates_redistribution_but_overlaps_one_sided_registration() {
        let p = NetParams::sarteco25();
        let mut c = case(20, 160);
        let base_rma = predict_reconfig(&p, &c, &shape(true));
        let base_col = predict_reconfig(&p, &c, &shape(false));
        c.spawn_tail = 10.0; // far beyond any registration time
        let rma = predict_reconfig(&p, &c, &shape(true));
        let col = predict_reconfig(&p, &c, &shape(false));
        // Two-sided waits out the whole tail.
        assert!(
            col.reconf_time - base_col.reconf_time >= 10.0 - 1e-9,
            "{} vs {}",
            col.reconf_time,
            base_col.reconf_time
        );
        // One-sided hides its registration inside the tail: the span
        // grows by less than the tail (the registration overlapped).
        assert!(rma.reconf_time > base_rma.reconf_time);
        assert!(
            rma.reconf_time - base_rma.reconf_time < 10.0,
            "registration did not overlap the spawn tail: {} vs {}",
            rma.reconf_time,
            base_rma.reconf_time
        );
        // A tail shorter than the registration is fully hidden.
        c.spawn_tail = base_rma.registration * 0.5;
        let hidden = predict_reconfig(&p, &c, &shape(true));
        assert_eq!(hidden.registration.to_bits(), base_rma.registration.to_bits());
    }

    #[test]
    fn warm_pool_prediction_drops_the_registration_term() {
        let p = NetParams::sarteco25();
        let mut s = shape(true);
        s.pool = true;
        let cold = predict_reconfig(&p, &case(20, 160), &s);
        let mut c = case(20, 160);
        c.warm = true;
        let warm = predict_reconfig(&p, &c, &s);
        assert!(warm.registration < cold.registration, "{warm:?} vs {cold:?}");
        assert!(warm.reconf_time < cold.reconf_time);
        // Warm registration is the fixed setup only: no per-byte term.
        assert!(warm.registration < 3.0 * (p.win_setup + 1e-3));
    }

    #[test]
    fn notify_sync_replaces_epochs_and_localizes_teardown() {
        let p = NetParams::sarteco25();
        let epoch = predict_reconfig(&p, &case(20, 160), &shape(true));
        let mut s = shape(true);
        s.notify_sync = true;
        let notify = predict_reconfig(&p, &case(20, 160), &s);
        // Per-op flags are orders of magnitude cheaper than passive
        // epochs at the calibrated constants, and teardown loses its
        // collective sync round.
        assert!(notify.protocol < epoch.protocol, "{notify:?} vs {epoch:?}");
        assert!(notify.teardown < epoch.teardown, "{notify:?} vs {epoch:?}");
        // Wire and registration are sync-mode independent.
        assert_eq!(notify.wire.to_bits(), epoch.wire.to_bits());
        assert_eq!(notify.registration.to_bits(), epoch.registration.to_bits());
        // Per-target epochs (RMA-Lock) gain even more from notify.
        let mut lk = shape(true);
        lk.lock_per_target = true;
        let lk_epoch = predict_reconfig(&p, &case(20, 160), &lk);
        lk.notify_sync = true;
        let lk_notify = predict_reconfig(&p, &case(20, 160), &lk);
        assert!(
            lk_epoch.protocol - lk_notify.protocol >= epoch.protocol - notify.protocol - 1e-15
        );
        // An absurd per-flag cost flips the comparison: the term is
        // really priced, not dropped.
        let mut slow = NetParams::sarteco25();
        slow.notify_overhead = 1.0;
        assert!(predict_reconfig(&slow, &case(20, 160), &s).protocol > epoch.protocol);
    }

    #[test]
    fn sched_cache_prices_cold_build_and_warm_replay() {
        let p = NetParams::sarteco25();
        let off = predict_reconfig(&p, &case(20, 160), &shape(true));
        let mut s = shape(true);
        s.sched_cache = true;
        let cold = predict_reconfig(&p, &case(20, 160), &s);
        let mut c = case(20, 160);
        c.sched_warm = true;
        let warm = predict_reconfig(&p, &c, &s);
        // Off charges nothing; cold pays the build, warm only the
        // validation handshake.
        assert!(cold.protocol > off.protocol);
        assert!(warm.protocol > off.protocol);
        assert!(warm.protocol < cold.protocol, "{warm:?} vs {cold:?}");
        let k = 3.0; // structures in case()
        assert!((warm.protocol - off.protocol - k * p.sched_validate).abs() < 1e-12);
        let accessed = 2.0; // 20 → 160 grow: ⌈20/160⌉ + 1
        let build = k * (p.sched_build + p.sched_per_target * 2.0 * accessed);
        assert!((cold.protocol - off.protocol - build).abs() < 1e-12);
        // Two-sided candidates never carry schedules: the flag is inert.
        let mut col = shape(false);
        col.sched_cache = true;
        assert_eq!(
            predict_reconfig(&p, &case(20, 160), &col).protocol.to_bits(),
            predict_reconfig(&p, &case(20, 160), &shape(false)).protocol.to_bits()
        );
    }

    #[test]
    fn per_wave_spawn_pricing_refines_the_single_tail_gate() {
        let p = NetParams::sarteco25();
        let mut c = case(20, 160);
        c.spawn_tail = 10.0; // far beyond any registration time
        let single = predict_reconfig(&p, &c, &shape(true));
        // One wave at the tail: the same gate plus one attach handshake.
        c.spawn_waves = vec![10.0];
        let one = predict_reconfig(&p, &c, &shape(true));
        assert!(
            (one.registration - (single.registration + p.op_overhead)).abs() < 1e-9,
            "{} vs {}",
            one.registration,
            single.registration
        );
        // Many waves: every merge attach stalls the eager stream, so
        // the gate can only grow with the wave count.
        c.spawn_waves = (1..=8).map(|j| 10.0 * j as f64 / 8.0).collect();
        let many = predict_reconfig(&p, &c, &shape(true));
        assert!(many.registration >= one.registration - 1e-12);
        assert!(many.registration >= 10.0);
        // Empty waves stay bit-identical to the legacy tail term.
        c.spawn_waves.clear();
        let legacy = predict_reconfig(&p, &c, &shape(true));
        assert_eq!(legacy.registration.to_bits(), single.registration.to_bits());
        assert_eq!(legacy.reconf_time.to_bits(), single.reconf_time.to_bits());
    }

    #[test]
    fn background_predictions_credit_overlap_and_never_shorten_the_span() {
        let p = NetParams::sarteco25();
        for one_sided in [false, true] {
            let blk = predict_reconfig(&p, &case(160, 20), &shape(one_sided));
            let mut s = shape(one_sided);
            s.background = true;
            let mut c = case(160, 20);
            // Background: the variable entry moves in the blocking tail.
            c.tail_bytes = vec![c.bulk_bytes.pop().unwrap()];
            let bg = predict_reconfig(&p, &c, &s);
            assert!(bg.overlap_iters >= 1.0, "{bg:?}");
            assert!(bg.overlap_credit > 0.0);
            // The span itself is never shorter than blocking: completion
            // is iteration-quantized and the tail still moves.
            assert!(bg.reconf_time >= blk.reconf_time - 1e-12, "{bg:?} vs {blk:?}");
            // ...but the effective cost can be, which is the whole point.
            assert!(bg.effective < bg.reconf_time);
        }
    }

    #[test]
    fn threading_prediction_pays_mt_penalties() {
        let p = NetParams::sarteco25();
        let base = predict_reconfig(&p, &case(20, 160), &shape(true));
        let mut s = shape(true);
        s.threading = true;
        let t = predict_reconfig(&p, &case(20, 160), &s);
        assert!(t.wire > base.wire, "MT must stretch one-sided wire time");
    }

    #[test]
    fn registration_shifts_the_col_vs_rma_balance() {
        // The paper's §VI premise, as seen by the predictor: at the
        // calibrated registration rate RMA loses the cold grow, and a
        // much faster registration rate flips the differential terms.
        let p = NetParams::sarteco25();
        let col = predict_reconfig(&p, &case(20, 160), &shape(false));
        let rma = predict_reconfig(&p, &case(20, 160), &shape(true));
        assert!(
            rma.registration > col.registration,
            "registration is the RMA-only term"
        );
        let mut fast = NetParams::sarteco25();
        fast.beta_register = 1.0 / 400.0e9;
        let rma_fast = predict_reconfig(&fast, &case(20, 160), &shape(true));
        assert!(rma_fast.registration < rma.registration);
    }

    #[test]
    fn reset_clears_occupancy() {
        let (mut cm, pl) = setup();
        cm.transfer(0.0, &pl, 0, 5, 1_000_000_000, TransferClass::Rma);
        assert!(cm.nic_backlog(&pl, 0, 0.0) > 0.0);
        cm.reset();
        assert_eq!(cm.nic_backlog(&pl, 0, 0.0), 0.0);
    }
}
