//! Calibrated model constants for the paper's testbed.
//!
//! Absolute numbers cannot be expected to match the authors' cluster —
//! the goal (DESIGN.md §5) is the *shape* of the results: who wins, by
//! roughly what factor, and where the crossovers fall.  Each constant
//! below is derived from public characteristics of the hardware/software
//! stack named in §V-A of the paper.

/// All tunable constants of the performance model.
#[derive(Clone, Debug)]
pub struct NetParams {
    // ----------------------------------------------------------- p2p
    /// Inter-node latency (s): IB EDR switched fabric, MPICH CH4/OFI
    /// verbs ~1.3–2 µs half round trip.
    pub alpha_inter: f64,
    /// Inter-node inverse bandwidth (s/B): 100 Gb/s EDR ≈ 12.5 GB/s
    /// peak; effective MPI bandwidth ≈ 11 GB/s.
    pub beta_inter: f64,
    /// Intra-node (shared-memory) latency (s).
    pub alpha_intra: f64,
    /// Intra-node inverse bandwidth (s/B): CMA / shm copy ≈ 8 GB/s
    /// per pair on Cascade Lake.
    pub beta_intra: f64,
    /// Eager→rendezvous switchover (B); MPICH default ~64 KiB on OFI.
    pub eager_threshold: u64,
    /// Extra handshake cost of the rendezvous protocol (RTS/CTS = one
    /// extra round trip) in seconds.
    pub rendezvous_rtt: f64,

    // ----------------------------------------------------------- CPU
    /// Pack/unpack (memcpy) inverse bandwidth (s/B) charged to the CPU
    /// of a rank actively driving two-sided communication.
    pub beta_memcpy: f64,
    /// Fixed software overhead per posted MPI operation (s).
    pub op_overhead: f64,
    /// Cost of one MPI_Test / request poll (s).
    pub poll_cost: f64,
    /// MPICH-CH4-style progress model: pending CPU work of nonblocking
    /// collectives (pack/unpack) is drained in chunks of this many
    /// bytes by each subsequent MPI call made by the rank.  This is
    /// what bounds how fast a background COL redistribution can
    /// complete when the app only calls MPI once per iteration, and
    /// hence drives the overlap-iteration counts of Fig. 6.
    pub progress_chunk: u64,

    // ----------------------------------------------------------- RMA
    /// Memory-registration inverse rate (s/B): ibv_reg_mr page-pinning
    /// throughput, ~5–10 GB/s on this class of hardware.  This is the
    /// dominant RMA overhead the paper identifies (§V-B, §VI).
    pub beta_register: f64,
    /// Fixed per-window setup/teardown cost per rank (s): allocation of
    /// window objects, rkey exchange bookkeeping.
    pub win_setup: f64,
    /// Per-target cost of opening/closing a passive epoch when
    /// MPI_MODE_NOCHECK is set (mostly local bookkeeping).
    pub epoch_cost: f64,
    /// Per-Get software initiation cost at the origin (s).
    pub get_overhead: f64,
    /// Origin-side cost of one notified RMA operation (s): flagging the
    /// target's notification counter rides the same packet as the data,
    /// so only a small software term remains — well under a passive
    /// epoch open/close pair (Quo Vadis MPI RMA?, notified access).
    pub notify_overhead: f64,

    // ------------------------------------ persistent schedules
    /// Fixed cost (s) of building one persistent redistribution
    /// schedule descriptor: allocating the descriptor, hashing the
    /// structure, publishing it to the job-level cache.
    pub sched_build: f64,
    /// Per-accessed-target cost (s) of the cold schedule build: block
    /// targets, read lists and segment layout are computed once per
    /// source this rank will touch.
    pub sched_per_target: f64,
    /// Cost (s) of validating a cached schedule on warm replay (shape
    /// and epoch check against the descriptor — no recomputation).
    pub sched_validate: f64,

    // ------------------------------------------------------ threading
    /// Compute-slowdown factor when a rank's core is shared with a
    /// busy-polling auxiliary thread (oversubscription, §V-D).
    pub oversub_factor: f64,
    /// MPICH 4.2.0's `MPI_THREAD_MULTIPLE` progress degradation (§V-D:
    /// "the environment does not support it"): collectives posted from
    /// a threaded context complete this many times slower (contended
    /// global lock thrashing between the main and auxiliary thread).
    pub mt_coll_penalty: f64,
    /// Additional wire-time multiplier for one-sided accesses to
    /// windows created from a threaded context — passive-target
    /// progress under MT is the worst MPICH path, which is why the
    /// paper measures per-iteration costs ≥100× for RMA-T (§V-D).
    pub mt_rma_penalty: f64,

    // ----------------------------------------------------- NIC lanes
    /// Cap on how much queued bulk traffic can delay a small-lane
    /// (latency-sensitive) message, in seconds.
    pub small_lane_max_wait: f64,

    // --------------------------------------------------------- spawn
    /// Fixed launch latency of one `MPI_Comm_spawn` round (s): the
    /// mpiexec/PMI bootstrap handshake paid once per spawn call,
    /// independent of how many processes it creates.
    pub spawn_launch: f64,
    /// Per-process startup cost (s): fork+exec, PMI wire-up and
    /// business-card exchange of one spawned rank.  Parallel spawning
    /// pays this once per *wave* (each source root launches its share
    /// concurrently) instead of once per process.
    pub spawn_per_proc: f64,
    /// Per-round cost (s) of `MPI_Intercomm_merge`: the merged
    /// intracommunicator is built in ⌈log2 ND⌉ rounds of rank
    /// renumbering/context agreement.
    pub merge_round: f64,
}

impl NetParams {
    /// Constants for the paper's testbed (§V-A).
    pub fn sarteco25() -> NetParams {
        NetParams {
            alpha_inter: 1.6e-6,
            // *Effective* per-NIC bandwidth for the bulk redistribution
            // patterns (many concurrent QPs, 20 ranks/NIC, rendezvous
            // pipelining): well below the 12.5 GB/s EDR line rate.
            beta_inter: 1.0 / 2.6e9,
            alpha_intra: 0.4e-6,
            beta_intra: 1.0 / 8.0e9,
            eager_threshold: 64 * 1024,
            rendezvous_rtt: 2.0 * 1.6e-6,
            beta_memcpy: 1.0 / 6.0e9,
            op_overhead: 0.3e-6,
            poll_cost: 0.1e-6,
            progress_chunk: 64 * 1024 * 1024,
            // ibv_reg_mr page-pinning throughput.  Calibrated so the
            // blocking RMA/COL ratio spans the paper's 0.73–0.99 band
            // across the 12 pairs (Fig. 3): registration of 64 GB/NS
            // per source dominates at small NS, vanishes at NS=160.
            beta_register: 1.0 / 3.7e9,
            win_setup: 30.0e-6,
            epoch_cost: 0.5e-6,
            get_overhead: 0.4e-6,
            // Notified completion: the counter update piggybacks on the
            // data packet; the origin pays a fraction of an epoch.
            notify_overhead: 0.05e-6,
            // Persistent-schedule terms: building a descriptor costs a
            // few µs plus a per-target term (the planning/targets work
            // the paper pays every resize); validating a cached one is
            // a single hash-and-compare.
            sched_build: 5.0e-6,
            sched_per_target: 0.2e-6,
            sched_validate: 1.0e-6,
            oversub_factor: 2.0,
            mt_coll_penalty: 2.0,
            mt_rma_penalty: 2.5,
            // Latency-sensitive messages (the CG dot-product rounds) can
            // queue up to this long behind bulk redistribution traffic —
            // the contention that drives ω to ~2.8 at (160→20), Fig. 5.
            small_lane_max_wait: 8.0e-3,
            // Decomposed `MPI_Comm_spawn` terms (parallel-spawning
            // study): Hydra bootstrap ~80 ms per spawn call, ~18 ms of
            // fork/exec + PMI wire-up per process, and a ~2 ms merge
            // round.  The legacy single-constant spawn model (0.25 s,
            // `RunSpec::spawn_cost`) remains the Sequential strategy's
            // calibration; these terms only drive Parallel/Async.
            spawn_launch: 0.08,
            spawn_per_proc: 0.018,
            merge_round: 2.0e-3,
        }
    }

    /// A deliberately tiny/fast configuration for unit tests: round
    /// numbers that make hand-computed expectations easy.
    pub fn test_simple() -> NetParams {
        NetParams {
            alpha_inter: 1e-3,
            beta_inter: 1e-9, // 1 GB/s
            alpha_intra: 1e-4,
            beta_intra: 1e-10, // 10 GB/s
            eager_threshold: 1024,
            rendezvous_rtt: 2e-3,
            beta_memcpy: 1e-10,
            op_overhead: 1e-6,
            poll_cost: 1e-7,
            progress_chunk: 1024 * 1024,
            beta_register: 1e-9,
            win_setup: 1e-4,
            epoch_cost: 1e-5,
            get_overhead: 1e-6,
            notify_overhead: 1e-6,
            sched_build: 5e-5,
            sched_per_target: 2e-6,
            sched_validate: 1e-5,
            oversub_factor: 2.0,
            mt_coll_penalty: 4.0,
            mt_rma_penalty: 8.0,
            small_lane_max_wait: 1e-3,
            spawn_launch: 0.05,
            spawn_per_proc: 0.01,
            merge_round: 1e-3,
        }
    }

    /// Effective inter-node bandwidth in B/s (for reports).
    pub fn inter_bandwidth(&self) -> f64 {
        1.0 / self.beta_inter
    }

    /// Clone-and-tweak builder, used by the drift scenarios to derive
    /// perturbed environments / miscalibrated beliefs from a seed:
    /// `NetParams::sarteco25().with(|p| p.beta_inter *= 4.0)`.
    pub fn with(mut self, f: impl FnOnce(&mut NetParams)) -> NetParams {
        f(&mut self);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sarteco_constants_are_sane() {
        let p = NetParams::sarteco25();
        // Effective collective bandwidth: below the 12.5 GB/s EDR line
        // rate but above gigabit-class fabrics.
        let bw = p.inter_bandwidth();
        assert!((1e9..=12.5e9).contains(&bw), "bw={bw}");
        // Latency in the µs regime.
        assert!(p.alpha_inter > 0.5e-6 && p.alpha_inter < 5e-6);
        // Registration slower than the wire would be pointless the other
        // way: pinning must cost less per byte than a full extra copy.
        assert!(p.beta_register < 2.0 * p.beta_inter * 10.0);
        // Eager threshold is KiB-scale.
        assert!(p.eager_threshold >= 4 * 1024 && p.eager_threshold <= 1024 * 1024);
        // Spawn terms: launch dominates one process's startup, and a
        // single parallel wave undercuts the 0.25 s sequential constant
        // (the parallel-spawning premise).
        assert!(p.spawn_launch > p.spawn_per_proc);
        assert!(p.spawn_launch + p.spawn_per_proc + 8.0 * p.merge_round < 0.25);
        // Notified completion must undercut an epoch pair, and a warm
        // schedule validation must undercut the cold build — otherwise
        // neither mechanism could ever pay off.
        assert!(p.notify_overhead < p.epoch_cost);
        assert!(p.sched_validate < p.sched_build);
    }

    #[test]
    fn with_builder_perturbs_only_the_named_terms() {
        let base = NetParams::sarteco25();
        let p = NetParams::sarteco25().with(|p| {
            p.beta_inter *= 4.0;
            p.spawn_per_proc *= 5.0;
        });
        assert_eq!(p.beta_inter.to_bits(), (base.beta_inter * 4.0).to_bits());
        assert_eq!(p.spawn_per_proc.to_bits(), (base.spawn_per_proc * 5.0).to_bits());
        assert_eq!(p.beta_register.to_bits(), base.beta_register.to_bits());
        assert_eq!(p.spawn_launch.to_bits(), base.spawn_launch.to_bits());
    }

    #[test]
    fn registration_dominates_for_large_windows() {
        // The core premise of the paper's negative result: for GB-scale
        // windows, registration time is comparable to transfer time.
        let p = NetParams::sarteco25();
        let bytes = 3.2e9; // 64 GB / 20 sources
        let reg = bytes * p.beta_register;
        let xfer = bytes * p.beta_inter;
        assert!(reg > 0.3 * xfer, "reg={reg} xfer={xfer}");
    }
}
