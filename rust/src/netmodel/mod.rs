//! Network and node performance model for the simulated cluster.
//!
//! Replaces the paper's physical testbed (8 nodes × 2× Intel Xeon 4210,
//! 100 Gbps InfiniBand EDR, MPICH 4.2.0 CH4:OFI/verbs) with a calibrated
//! analytical model:
//!
//! * [`topology`]  — nodes, cores, rank placement (⌈N/20⌉ nodes, §V-A),
//! * [`costmodel`] — α-β point-to-point costs, eager/rendezvous regimes,
//!   two-lane NIC contention (bulk FIFO occupancy + small-message lane),
//!   RMA window registration and epoch costs, plus the closed-form
//!   reconfiguration-cost predictions driving `mam::planner`,
//! * [`calibration`] — the constants and their derivations.

pub mod calibration;
pub mod costmodel;
pub mod topology;

pub use calibration::NetParams;
pub use costmodel::{
    expected_spawn_retry_tail, intercomm_merge_cost, moved_bytes, predict_reconfig, CostModel,
    CostPrediction, ReconfigCase, RedistShape, SpawnSchedule, TransferClass,
};
pub use topology::{NodeId, Placement, Topology};
