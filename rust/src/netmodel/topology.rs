//! Cluster topology: nodes, cores and rank placement.
//!
//! The paper's testbed is 8 nodes × 20 cores (two 10-core Xeon 4210)
//! on one InfiniBand switch.  Placement follows §V-A: a run with
//! `N = max(NS, ND)` ranks uses `⌈N/20⌉` nodes and ranks are laid out
//! block-wise (ranks 0..19 on node 0, 20..39 on node 1, …), which is
//! MPICH's default `-bind-to core -map-by node`-free layout for one
//! process per core.

/// Identifier of a physical node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// How core slots map to nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacePolicy {
    /// Slot `s` → node `s / cores_per_node` (fill node 0 first).
    Block,
    /// Slot `s` → node `s % nodes` (round-robin).  This is the layout
    /// of the paper's dynamic jobs: the allocation spans ⌈N/20⌉ nodes
    /// (§V-A) and *both* the source and the drain group are spread over
    /// every allocated node, so reconfiguration traffic uses all NICs
    /// in parallel rather than funnelling through node 0.
    Cyclic,
}

/// Static cluster description.
#[derive(Clone, Debug)]
pub struct Topology {
    pub nodes: usize,
    pub cores_per_node: usize,
    pub policy: PlacePolicy,
}

impl Topology {
    /// The paper's cluster: 8 nodes × 20 cores, cyclic rank layout.
    pub fn sarteco25() -> Topology {
        Topology { nodes: 8, cores_per_node: 20, policy: PlacePolicy::Cyclic }
    }

    pub fn new(nodes: usize, cores_per_node: usize) -> Topology {
        assert!(nodes > 0 && cores_per_node > 0);
        Topology { nodes, cores_per_node, policy: PlacePolicy::Block }
    }

    pub fn new_cyclic(nodes: usize, cores_per_node: usize) -> Topology {
        assert!(nodes > 0 && cores_per_node > 0);
        Topology { nodes, cores_per_node, policy: PlacePolicy::Cyclic }
    }

    pub fn total_cores(&self) -> usize {
        self.nodes * self.cores_per_node
    }

    /// Node hosting core slot `s` under this topology's policy.
    pub fn node_of_slot(&self, slot: usize) -> NodeId {
        debug_assert!(slot < self.total_cores());
        match self.policy {
            PlacePolicy::Block => NodeId(slot / self.cores_per_node),
            PlacePolicy::Cyclic => NodeId(slot % self.nodes),
        }
    }

    /// Nodes needed for `n` ranks at one rank per core (§V-A: ⌈N/20⌉).
    pub fn nodes_for(&self, n_ranks: usize) -> usize {
        n_ranks.div_ceil(self.cores_per_node)
    }
}

/// Mapping from global rank to node, block-wise.
#[derive(Clone, Debug)]
pub struct Placement {
    pub cores_per_node: usize,
    /// node of each rank (index = rank).
    pub node_of: Vec<NodeId>,
}

impl Placement {
    /// Block placement of `n_ranks` ranks over a topology; panics if the
    /// cluster is too small (paper never oversubscribes at placement).
    pub fn block(topo: &Topology, n_ranks: usize) -> Placement {
        let needed = topo.nodes_for(n_ranks);
        assert!(
            needed <= topo.nodes,
            "placement needs {needed} nodes but topology has {}",
            topo.nodes
        );
        let node_of = (0..n_ranks)
            .map(|r| NodeId(r / topo.cores_per_node))
            .collect();
        Placement { cores_per_node: topo.cores_per_node, node_of }
    }

    /// Cyclic (round-robin) placement over all of the topology's nodes.
    pub fn cyclic(topo: &Topology, n_ranks: usize) -> Placement {
        assert!(n_ranks <= topo.total_cores(), "cluster too small");
        let node_of = (0..n_ranks).map(|r| NodeId(r % topo.nodes)).collect();
        Placement { cores_per_node: topo.cores_per_node, node_of }
    }

    /// Placement for a reconfiguration pair (NS → ND): ranks of *both*
    /// groups coexist during redistribution; MaM's Merge method reuses
    /// ranks 0..min(NS,ND) and spawns/retires the tail, so the union
    /// occupies `max(NS, ND)` cores with block layout (§V-A).
    pub fn for_pair(topo: &Topology, ns: usize, nd: usize) -> Placement {
        Placement::block(topo, ns.max(nd))
    }

    pub fn node_of(&self, rank: usize) -> NodeId {
        self.node_of[rank]
    }

    pub fn n_ranks(&self) -> usize {
        self.node_of.len()
    }

    /// Number of distinct nodes used.
    pub fn n_nodes(&self) -> usize {
        self.node_of.iter().map(|n| n.0).max().map_or(0, |m| m + 1)
    }

    /// Are two ranks on the same node (shared-memory path)?
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of[a] == self.node_of[b]
    }

    /// Ranks hosted on `node`.
    pub fn ranks_on(&self, node: NodeId) -> Vec<usize> {
        (0..self.n_ranks())
            .filter(|&r| self.node_of[r] == node)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sarteco_topology_matches_paper() {
        let t = Topology::sarteco25();
        assert_eq!(t.nodes, 8);
        assert_eq!(t.cores_per_node, 20);
        assert_eq!(t.total_cores(), 160);
    }

    #[test]
    fn nodes_for_matches_ceiling_rule() {
        let t = Topology::sarteco25();
        assert_eq!(t.nodes_for(20), 1);
        assert_eq!(t.nodes_for(21), 2);
        assert_eq!(t.nodes_for(40), 2);
        assert_eq!(t.nodes_for(80), 4);
        assert_eq!(t.nodes_for(160), 8);
    }

    #[test]
    fn block_placement_layout() {
        let t = Topology::sarteco25();
        let p = Placement::block(&t, 40);
        assert_eq!(p.node_of(0), NodeId(0));
        assert_eq!(p.node_of(19), NodeId(0));
        assert_eq!(p.node_of(20), NodeId(1));
        assert_eq!(p.node_of(39), NodeId(1));
        assert_eq!(p.n_nodes(), 2);
        assert!(p.same_node(3, 12));
        assert!(!p.same_node(3, 22));
    }

    #[test]
    fn pair_placement_uses_max() {
        let t = Topology::sarteco25();
        let p = Placement::for_pair(&t, 20, 160);
        assert_eq!(p.n_ranks(), 160);
        assert_eq!(p.n_nodes(), 8);
        let p = Placement::for_pair(&t, 160, 40);
        assert_eq!(p.n_ranks(), 160);
    }

    #[test]
    #[should_panic(expected = "placement needs")]
    fn oversized_placement_panics() {
        let t = Topology::new(2, 4);
        Placement::block(&t, 9);
    }

    #[test]
    fn ranks_on_node() {
        let t = Topology::new(2, 3);
        let p = Placement::block(&t, 5);
        assert_eq!(p.ranks_on(NodeId(0)), vec![0, 1, 2]);
        assert_eq!(p.ranks_on(NodeId(1)), vec![3, 4]);
    }
}
