//! `proteo` — the command-line launcher.
//!
//! ```text
//! proteo exp fig3            # regenerate a paper figure (fig3..fig10, all)
//! proteo run --ns 20 --nd 160 --method rma-lockall --strategy wd
//! proteo run --ns 20 --nd 160 --planner auto   # cost-model-driven choice
//! proteo scenario --quick --compare            # closed-loop RMS trace
//! proteo scenario --drift all --quick          # static vs recalibrating planner
//! proteo scenario --faults spawn=first1 --quick  # deterministic fault injection
//! proteo chaos --quick       # fault-matrix sweep: recovery vs rollback
//! proteo ablation single-window
//! proteo ablation register-sweep --ns 20 --nd 160
//! proteo ablation sched-cache    # cold build vs warm replay vs cache off
//! proteo cg --iters 200      # AOT JAX/Pallas CG through PJRT
//! proteo info                # calibration, artifact manifest, versions
//! ```

use std::process::ExitCode;

use proteo::config::ExperimentConfig;
use proteo::experiments::{self, ablation, chaos, drift, scenario, smoke, FigOptions};
use proteo::linalg::EllMatrix;
use proteo::mam::{Method, PlannerMode, SpawnStrategy, Strategy, WinPoolPolicy};
use proteo::netmodel::NetParams;
use proteo::proteo::{run_median, RunSpec};
use proteo::runtime::{artifacts_dir, CgRuntime};
use proteo::simmpi::{FaultSpec, RmaSync};
use proteo::util::benchkit::compare_bench;
use proteo::util::cli::{parse_toggle, Args, Cli, Command};
use proteo::util::json::Json;
use proteo::util::stats::{fmt_bytes, fmt_seconds};
use proteo::util::wallclock::WallTimer;

fn cli() -> Cli {
    Cli {
        prog: "proteo",
        about: "malleable-MPI reconfiguration study (CS.DC 2025 reproduction)",
        commands: vec![
            Command::new("exp", "regenerate a paper figure (fig3..fig10 or 'all')")
                .opt("reps", "3", "repetitions per point (paper: 20)")
                .opt("scale", "1", "divide the problem size by this factor")
                .opt("pairs", "", "comma list like 20:160,160:20 (default: all 12)")
                .opt("seed", "12648430", "base RNG seed")
                .opt("win-pool", "off", "add +pool variants to the version sets: on | off")
                .flag("quick", "CI-sized sweep (scale 100, 4 pairs, 1 rep)"),
            Command::new("run", "run a single reconfiguration experiment")
                .opt("config", "", "JSON config file (overrides other options)")
                .opt("ns", "20", "source ranks")
                .opt("nd", "160", "drain ranks")
                .opt("method", "col", "col | rma-lock | rma-lockall")
                .opt("strategy", "blocking", "blocking | nb | wd | t")
                .opt("reps", "3", "repetitions (median reported)")
                .opt("scale", "1", "problem-size divisor")
                .opt("seed", "12648430", "base RNG seed")
                .opt("win-pool", "off", "persistent RMA window pool (§VI): on | off")
                .opt("win-pool-cap", "0", "per-rank pin-cache bound (0 = unbounded)")
                .opt("spawn-strategy", "sequential", "sequential | parallel | async")
                .opt("rma-chunk", "0", "pipelined RMA registration chunk (KiB; 0 = off)")
                .opt(
                    "rma-dereg",
                    "on",
                    "pipelined deregistration (teardown half of --rma-chunk): on | off",
                )
                .opt("planner", "fixed", "fixed | auto (cost-model-driven version choice)")
                .opt("recalib", "off", "online NetParams recalibration (auto planner): on | off")
                .opt("rma-sync", "epoch", "RMA completion sync: epoch | notify")
                .opt("sched-cache", "off", "persistent redistribution schedules: on | off")
                .opt("faults", "", "deterministic fault injection spec: k=v,... or @file")
                .flag("json", "emit the result as JSON"),
            Command::new(
                "scenario",
                "closed-loop RMS job-trace simulation with per-resize planning",
            )
            .opt("planner", "auto", "fixed | auto")
            .opt("method", "col", "fixed version: col | rma-lock | rma-lockall")
            .opt("strategy", "blocking", "fixed version: blocking | nb | wd | t")
            .opt("spawn-strategy", "sequential", "fixed version: sequential | parallel | async")
            .opt("win-pool", "off", "fixed version: on | off")
            .opt("rma-chunk", "0", "fixed version: pipelined chunk (KiB; 0 = off)")
            .opt("recalib", "off", "online NetParams recalibration (auto planner): on | off")
            .opt("rma-sync", "epoch", "RMA completion sync: epoch | notify")
            .opt("sched-cache", "off", "persistent redistribution schedules: on | off")
            .opt("faults", "", "deterministic fault injection spec: k=v,... or @file")
            .opt("drift", "", "run a drift benchmark instead: miscal | hetero | congest | all")
            .opt("seed", "12648430", "base RNG seed")
            .flag("quick", "CI-sized workload (10000x smaller problem)")
            .flag("compare", "also run the fixed anchor versions and print makespans")
            .flag("json", "emit the report as JSON"),
            Command::new(
                "chaos",
                "fault-injection sweep: the closed-loop RMS trace under a matrix of fault specs",
            )
            .flag("quick", "CI-sized workload (10000x smaller problem)")
            .flag("json", "emit the report as JSON"),
            Command::new(
                "ablation",
                "ablations: single-window | register-sweep | eager-sweep | win-pool | spawn | \
                 rma-chunk | rma-chunk-shrink | recalib | sched-cache",
            )
            .opt("ns", "20", "source ranks (register-sweep)")
            .opt("nd", "160", "drain ranks (register-sweep)")
            .opt("reps", "1", "repetitions")
            .opt("scale", "1", "problem-size divisor")
            .flag("quick", "CI-sized sweep"),
            Command::new("cg", "run the AOT JAX/Pallas CG through PJRT")
                .opt("iters", "200", "max iterations")
                .opt("tol", "1e-5", "relative residual target")
                .opt("artifacts", "", "artifacts dir (default: $PROTEO_ARTIFACTS or artifacts/)"),
            Command::new(
                "engine-stress",
                "million-rank DES stress: resize-shaped workload on lite activities",
            )
            .opt("ranks", "1048576", "post-resize rank count ND")
            .opt("ns", "0", "pre-resize rank count NS (0 = ND/2)")
            .opt("rounds", "4", "barrier rounds (resize commit at the middle one)"),
            Command::new("bench-smoke", "collect deterministic bench metrics as JSON")
                .opt("out", "BENCH_pr.json", "output path")
                .flag("quick", "CI-sized workload"),
            Command::new("bench-compare", "gate: compare two bench-smoke JSON files")
                .opt("tol", "0.10", "allowed relative regression before failing"),
            Command::new(
                "bench-promote",
                "promote a green bench-smoke JSON into the committed baseline",
            )
            .opt("out", "BENCH_baseline.json", "baseline path to (over)write"),
            Command::new("audit", "static determinism & concurrency lints over rust/src")
                .opt("root", "", "source root to scan (default: rust/src, then src)")
                .flag("deny", "exit nonzero on any finding (the CI gate)")
                .flag("json", "emit findings as JSON instead of text"),
            Command::new("info", "print calibration constants and artifact manifest"),
        ],
    }
}

fn parse_pairs(s: &str) -> Result<Vec<(usize, usize)>, String> {
    if s.is_empty() {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|p| {
            let (a, b) = p.split_once(':').ok_or_else(|| format!("bad pair '{p}' (want ns:nd)"))?;
            let ns: usize = a.trim().parse().map_err(|_| format!("bad ns in '{p}'"))?;
            let nd: usize = b.trim().parse().map_err(|_| format!("bad nd in '{p}'"))?;
            if ns == 0 || nd == 0 || ns == nd {
                return Err(format!("invalid pair {ns}:{nd}"));
            }
            Ok((ns, nd))
        })
        .collect()
}

/// Parse a `--faults` argument: empty = off, `@path` reads the spec
/// from a file (trailing whitespace/newline trimmed), anything else is
/// the `k=v,...` spec itself.
fn parse_faults(args: &Args) -> Result<Option<FaultSpec>, String> {
    let s = args.get("faults").unwrap_or("");
    if s.is_empty() {
        return Ok(None);
    }
    let text = match s.strip_prefix('@') {
        Some(path) => std::fs::read_to_string(path).map_err(|e| format!("--faults {path}: {e}"))?,
        None => s.to_string(),
    };
    FaultSpec::parse(text.trim()).map(Some).map_err(|e| format!("bad --faults: {e}"))
}

fn fig_options(args: &Args) -> Result<FigOptions, String> {
    let quick = args.flag("quick");
    let mut opts = if quick { FigOptions::quick() } else { FigOptions::default() };
    // Under `--quick`, only *explicitly passed* options override the
    // preset — the command's seeded defaults must not silently undo it
    // (e.g. the default `--scale 1` turning a quick sweep full-scale).
    let get = |name: &str| {
        if quick {
            args.get_explicit(name)
        } else {
            args.get(name)
        }
    };
    if let Some(r) = get("reps") {
        let r: usize = r.parse().map_err(|_| format!("bad --reps '{r}' (integer)"))?;
        opts.reps = r.max(1);
    }
    if let Some(s) = get("scale") {
        let s: u64 = s.parse().map_err(|_| format!("bad --scale '{s}' (integer)"))?;
        opts.scale = s.max(1);
    }
    if let Some(seed) = get("seed") {
        opts.seed = seed.parse().map_err(|_| format!("bad --seed '{seed}' (integer)"))?;
    }
    if let Some(p) = get("pairs") {
        let pairs = parse_pairs(p)?;
        if !pairs.is_empty() {
            opts.pairs = pairs;
        }
    }
    if let Some(wp) = get("win-pool") {
        opts.pool_variants = parse_toggle(wp).ok_or("bad --win-pool (on | off)")?;
    }
    Ok(opts)
}

fn cmd_exp(args: &Args) -> Result<(), String> {
    let which = args
        .positionals()
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let opts = fig_options(args)?;
    let figs: Vec<&str> = if which == "all" {
        vec!["fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10"]
    } else {
        vec![which.as_str()]
    };
    for f in figs {
        let table = experiments::by_name(f, &opts)
            .ok_or_else(|| format!("unknown figure '{f}' (want fig3..fig10)"))?;
        println!("{}", table.render());
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let results = if let Some(path) = args.get("config").filter(|s| !s.is_empty()) {
        let cfg = ExperimentConfig::from_file(path)?;
        cfg.pairs
            .iter()
            .map(|&(ns, nd)| run_median(&cfg.spec_for(ns, nd), cfg.reps))
            .collect::<Vec<_>>()
    } else {
        let ns = args.get_usize("ns").ok_or("bad --ns")?;
        let nd = args.get_usize("nd").ok_or("bad --nd")?;
        let method = Method::parse(args.get("method").unwrap_or("col"))
            .ok_or("bad --method (col | rma-lock | rma-lockall)")?;
        let strategy = Strategy::parse(args.get("strategy").unwrap_or("blocking"))
            .ok_or("bad --strategy (blocking | nb | wd | t)")?;
        if !proteo::mam::is_valid_version(method, strategy) {
            return Err("NB is undefined for RMA methods (§V-A); use WD".into());
        }
        let mut spec = RunSpec::sarteco25(ns, nd, method, strategy);
        spec.win_pool = args
            .get("win-pool")
            .and_then(WinPoolPolicy::parse)
            .ok_or("bad --win-pool (on | off)")?
            .with_cap(
                args.get_usize("win-pool-cap")
                    .ok_or("bad --win-pool-cap (non-negative integer)")?,
            );
        spec.spawn_strategy = args
            .get("spawn-strategy")
            .and_then(SpawnStrategy::parse)
            .ok_or("bad --spawn-strategy (sequential | parallel | async)")?;
        spec.rma_chunk_kib = args
            .get("rma-chunk")
            .and_then(|s| s.parse::<u64>().ok())
            .ok_or("bad --rma-chunk (KiB, non-negative integer; 0 = off)")?;
        spec.rma_dereg = args
            .get("rma-dereg")
            .and_then(parse_toggle)
            .ok_or("bad --rma-dereg (on | off)")?;
        spec.planner = args
            .get("planner")
            .and_then(PlannerMode::parse)
            .ok_or("bad --planner (fixed | auto)")?;
        spec.recalib = args
            .get("recalib")
            .and_then(parse_toggle)
            .ok_or("bad --recalib (on | off)")?;
        spec.rma_sync = args
            .get("rma-sync")
            .and_then(RmaSync::parse)
            .ok_or("bad --rma-sync (epoch | notify)")?;
        spec.sched_cache = args
            .get("sched-cache")
            .and_then(parse_toggle)
            .ok_or("bad --sched-cache (on | off)")?;
        spec.faults = parse_faults(args)?;
        if let Some(seed) = args.get("seed").and_then(|s| s.parse::<u64>().ok()) {
            spec.seed = seed;
        }
        let scale = args.get_usize("scale").unwrap_or(1).max(1) as u64;
        if scale > 1 {
            spec.sam.matrix_elems /= scale;
            spec.sam.colind_elems /= scale;
            spec.sam.rowptr_elems = (spec.sam.rowptr_elems / scale).max(16);
            spec.sam.vector_elems = (spec.sam.vector_elems / scale).max(16);
            spec.sam.flops_per_iter /= scale as f64;
        }
        vec![run_median(&spec, args.get_usize("reps").unwrap_or(3).max(1))]
    };
    for r in results {
        if args.flag("json") {
            let j = Json::obj(vec![
                ("version", Json::str(r.label.clone())),
                ("ns", Json::num(r.ns as f64)),
                ("nd", Json::num(r.nd as f64)),
                ("redist_time_s", Json::num(r.redist_time)),
                ("reconf_total_s", Json::num(r.reconf_total)),
                ("n_it", Json::num(r.n_it)),
                ("t_base_s", Json::num(r.t_base)),
                ("t_bg_s", Json::num(r.t_bg)),
                ("t_it_nd_s", Json::num(r.t_it_nd)),
                ("omega", Json::num(r.omega)),
                ("events", Json::num(r.events as f64)),
            ]);
            println!("{}", j.to_pretty());
        } else {
            println!(
                "{:<16} {:>3}->{:<3}  R={:>10}  total={:>10}  n_it={:>4}  t_base={} t_bg={} t_nd={}  omega={:.2}",
                r.label,
                r.ns,
                r.nd,
                fmt_seconds(r.redist_time),
                fmt_seconds(r.reconf_total),
                r.n_it,
                fmt_seconds(r.t_base),
                fmt_seconds(r.t_bg),
                fmt_seconds(r.t_it_nd),
                r.omega,
            );
        }
    }
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<(), String> {
    let which = args
        .positionals()
        .first()
        .cloned()
        .unwrap_or_else(|| "single-window".to_string());
    let opts = fig_options(args)?;
    match which.as_str() {
        "single-window" => println!("{}", ablation::single_window(&opts).render()),
        "register-sweep" => {
            let ns = args.get_usize("ns").ok_or("bad --ns")?;
            let nd = args.get_usize("nd").ok_or("bad --nd")?;
            println!("{}", ablation::registration_sweep(&opts, ns, nd).render());
        }
        "eager-sweep" => {
            let ns = args.get_usize("ns").ok_or("bad --ns")?;
            let nd = args.get_usize("nd").ok_or("bad --nd")?;
            println!("{}", ablation::eager_sweep(&opts, ns, nd).render());
        }
        "win-pool" => println!("{}", ablation::win_pool(&opts).render()),
        "spawn" => println!("{}", ablation::spawn_strategies(&opts).render()),
        "rma-chunk" => println!("{}", ablation::rma_chunk(&opts).render()),
        "rma-chunk-shrink" => println!("{}", ablation::rma_chunk_shrink(&opts).render()),
        "recalib" => println!("{}", ablation::recalib(&opts).render()),
        "sched-cache" => println!("{}", ablation::sched_cache(&opts).render()),
        other => return Err(format!("unknown ablation '{other}'")),
    }
    Ok(())
}

fn cmd_scenario(args: &Args) -> Result<(), String> {
    if let Some(which) = args.get("drift").filter(|s| !s.is_empty()) {
        // Drift benchmarks compare the static planner against the
        // online-recalibrating one under a model/environment mismatch;
        // they replace the RMS trace entirely.
        let quick = args.flag("quick");
        let scenarios = if which == "all" {
            drift::DriftScenario::all(quick)
        } else {
            vec![drift::DriftScenario::by_name(which, quick).ok_or_else(|| {
                format!("unknown drift scenario '{which}' (miscal | hetero | congest | all)")
            })?]
        };
        for sc in &scenarios {
            let report = drift::run_drift(sc);
            if args.flag("json") {
                println!("{}", report.to_json().to_pretty());
            } else {
                println!("{}", report.render(args.flag("compare")));
            }
        }
        return Ok(());
    }
    let mut spec = scenario::ScenarioSpec::rms_trace(args.flag("quick"));
    spec.planner = args
        .get("planner")
        .and_then(PlannerMode::parse)
        .ok_or("bad --planner (fixed | auto)")?;
    spec.method = Method::parse(args.get("method").unwrap_or("col"))
        .ok_or("bad --method (col | rma-lock | rma-lockall)")?;
    spec.strategy = Strategy::parse(args.get("strategy").unwrap_or("blocking"))
        .ok_or("bad --strategy (blocking | nb | wd | t)")?;
    spec.spawn_strategy = args
        .get("spawn-strategy")
        .and_then(SpawnStrategy::parse)
        .ok_or("bad --spawn-strategy (sequential | parallel | async)")?;
    spec.win_pool = args
        .get("win-pool")
        .and_then(WinPoolPolicy::parse)
        .ok_or("bad --win-pool (on | off)")?;
    spec.rma_chunk_kib = args
        .get("rma-chunk")
        .and_then(|s| s.parse::<u64>().ok())
        .ok_or("bad --rma-chunk (KiB, non-negative integer; 0 = off)")?;
    spec.recalib = args
        .get("recalib")
        .and_then(parse_toggle)
        .ok_or("bad --recalib (on | off)")?;
    spec.rma_sync = args
        .get("rma-sync")
        .and_then(RmaSync::parse)
        .ok_or("bad --rma-sync (epoch | notify)")?;
    spec.sched_cache = args
        .get("sched-cache")
        .and_then(parse_toggle)
        .ok_or("bad --sched-cache (on | off)")?;
    spec.faults = parse_faults(args)?;
    if spec.planner == PlannerMode::Fixed
        && !proteo::mam::is_valid_version(spec.method, spec.strategy)
    {
        return Err("NB is undefined for RMA methods (§V-A); use WD".into());
    }
    if let Some(seed) = args.get("seed").and_then(|s| s.parse::<u64>().ok()) {
        spec.seed = seed;
    }
    if args.flag("compare") {
        if args.flag("json") {
            return Err("--compare renders a text table; drop --json".into());
        }
        println!("{}", scenario::makespan_comparison(&spec).render());
        return Ok(());
    }
    let report = scenario::run_scenario(&spec);
    if args.flag("json") {
        println!("{}", report.to_json().to_pretty());
    } else {
        println!("{}", report.render());
    }
    Ok(())
}

fn cmd_chaos(args: &Args) -> Result<(), String> {
    let report = chaos::run_chaos(args.flag("quick"));
    if args.flag("json") {
        println!("{}", report.to_json().to_pretty());
    } else {
        print!("{}", report.render());
    }
    Ok(())
}

fn cmd_cg(args: &Args) -> Result<(), String> {
    let dir = match args.get("artifacts").filter(|s| !s.is_empty()) {
        Some(d) => std::path::PathBuf::from(d),
        None => artifacts_dir(),
    };
    let rt = CgRuntime::load(&dir).map_err(|e| format!("{e:#}"))?;
    let m = rt.manifest.clone();
    println!(
        "platform={} artifact: n={} grid={} blocks=({},{},{},{}) vmem/step={} mxu flops/step={}",
        rt.platform(),
        m.n,
        m.grid,
        m.nbr,
        m.k,
        m.br,
        m.bc,
        fmt_bytes(m.vmem_bytes_per_step),
        m.mxu_flops_per_step,
    );
    let a = EllMatrix::laplacian_2d(m.grid);
    let b: Vec<f32> = (0..m.n).map(|i| 1.0 + ((i % 7) as f32) * 0.125).collect();
    let tol: f32 = args.get("tol").and_then(|s| s.parse().ok()).unwrap_or(1e-5);
    let iters = args.get_usize("iters").unwrap_or(200);
    let t0 = WallTimer::start();
    let (st, history) = rt.cg_solve(&a, &b, tol, iters).map_err(|e| format!("{e:#}"))?;
    let wall = t0.elapsed_s();
    let done = history.len() - 1;
    println!(
        "CG: {} iterations, rel residual {:.3e}, rr={:.3e}, wall {:.3}s ({:.2} ms/iter)",
        done,
        history.last().unwrap(),
        st.rr,
        wall,
        1e3 * wall / done.max(1) as f64,
    );
    for (i, r) in history.iter().enumerate() {
        if i % 20 == 0 || i + 1 == history.len() {
            println!("  iter {i:>4}: rel residual {r:.3e}");
        }
    }
    Ok(())
}

fn cmd_engine_stress(args: &Args) -> Result<(), String> {
    let nd = args.get_usize("ranks").ok_or("bad --ranks")?;
    let ns = match args.get_usize("ns").ok_or("bad --ns")? {
        0 => (nd / 2).max(1),
        n => n,
    };
    let rounds = args.get_usize("rounds").ok_or("bad --rounds")? as u64;
    if ns > nd {
        return Err(format!("--ns {ns} exceeds --ranks {nd}"));
    }
    if rounds < 2 {
        return Err("--rounds must be at least 2".into());
    }
    let rep = proteo::experiments::stress::engine_stress(ns, nd, rounds);
    print!("{}", rep.render());
    Ok(())
}

fn cmd_bench_smoke(args: &Args) -> Result<(), String> {
    let out = args.get("out").unwrap_or("BENCH_pr.json").to_string();
    let t0 = WallTimer::start();
    let mut doc = smoke::collect(args.flag("quick"));
    let wall = t0.elapsed_s();
    // Informational wall-clock provenance: never gated (bench-compare
    // only reads "entries"/"schema"/"mode"), but recorded so regressions
    // of the *simulator's own* speed are visible in the artifacts.
    if let Json::Obj(o) = &mut doc {
        o.insert("wall_s".to_string(), Json::Num(wall));
    }
    std::fs::write(&out, doc.to_pretty()).map_err(|e| format!("{out}: {e}"))?;
    let n = doc.get("entries").and_then(|e| e.as_obj()).map_or(0, |o| o.len());
    println!("wrote {n} deterministic bench entries to {out} ({wall:.2}s wall)");
    Ok(())
}

fn cmd_bench_compare(args: &Args) -> Result<(), String> {
    let [baseline, current] = args.positionals() else {
        return Err("usage: proteo bench-compare <baseline.json> <current.json>".into());
    };
    let tol = args.get_f64("tol").ok_or("bad --tol")?;
    let load = |path: &str| -> Result<Json, String> {
        let src = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Json::parse(&src).map_err(|e| format!("{path}: {e}"))
    };
    let cmp = compare_bench(&load(baseline)?, &load(current)?, tol);
    for note in &cmp.notes {
        println!("note: {note}");
    }
    if cmp.passed() {
        println!(
            "bench gate OK: {} entries within {:.0}% of {baseline}",
            cmp.compared,
            tol * 100.0
        );
        Ok(())
    } else {
        for r in &cmp.regressions {
            eprintln!("REGRESSION: {r}");
        }
        Err(format!(
            "{} regression(s) beyond {:.0}% vs {baseline} ({} entries compared)",
            cmp.regressions.len(),
            tol * 100.0,
            cmp.compared
        ))
    }
}

fn cmd_bench_promote(args: &Args) -> Result<(), String> {
    let [src] = args.positionals() else {
        return Err(
            "usage: proteo bench-promote <BENCH_pr.json> [--out BENCH_baseline.json]".into()
        );
    };
    let out = args.get("out").unwrap_or("BENCH_baseline.json").to_string();
    let doc = {
        let s = std::fs::read_to_string(src).map_err(|e| format!("{src}: {e}"))?;
        Json::parse(&s).map_err(|e| format!("{src}: {e}"))?
    };
    let entries = doc
        .get("entries")
        .and_then(|e| e.as_obj())
        .ok_or("source has no \"entries\" object")?;
    if entries.is_empty() {
        return Err("refusing to promote an empty entry set (still bootstrap)".into());
    }
    // Rewrite the note: the bootstrap wording of the pre-promotion
    // baseline would misdescribe an armed file.
    let note = format!(
        "Armed baseline for the CI bench-smoke regression gate (virtual-time metrics; \
         fully deterministic), promoted from {src} via `proteo bench-promote`. \
         `proteo bench-compare {out} BENCH_pr.json --tol 0.10` fails the job when any \
         entry regresses by more than 10%. Re-promote a green run's BENCH_pr.json \
         artifact to refresh it."
    );
    let mut fields = vec![
        ("entries", Json::Obj(entries.clone())),
        ("mode", doc.get("mode").cloned().unwrap_or_else(|| Json::str("quick"))),
        ("note", Json::str(note)),
        ("schema", doc.get("schema").cloned().unwrap_or(Json::Num(1.0))),
    ];
    // Carry the wall clock forward so the soft wall_s comparison in
    // bench-compare has a baseline to warn against.
    if let Some(w) = doc.get("wall_s").cloned() {
        fields.push(("wall_s", w));
    }
    let out_doc = Json::obj(fields);
    std::fs::write(&out, out_doc.to_pretty()).map_err(|e| format!("{out}: {e}"))?;
    println!("promoted {} entries from {src} into {out}", entries.len());
    Ok(())
}

fn cmd_audit(args: &Args) -> Result<(), String> {
    let root = match args.get("root") {
        Some(r) if !r.is_empty() => std::path::PathBuf::from(r),
        _ => ["rust/src", "src"]
            .iter()
            .map(std::path::PathBuf::from)
            .find(|p| p.is_dir())
            .ok_or("no rust/src or src directory here; pass --root")?,
    };
    let findings = proteo::analysis::audit_tree(&root)?;
    if args.flag("json") {
        let arr: Vec<Json> = findings
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("file", Json::str(&f.file)),
                    ("line", Json::Num(f.line as f64)),
                    ("lint", Json::str(f.lint)),
                    ("message", Json::str(&f.message)),
                ])
            })
            .collect();
        println!("{}", Json::Arr(arr).to_pretty());
    } else {
        for f in &findings {
            println!("{f}");
            if let Some(why) = proteo::analysis::rationale(f.lint) {
                println!("    why: {why}");
            }
            println!("    suppress: // audit:allow({}, <reason>)", f.lint);
        }
        println!(
            "audit: {} finding(s) in {}{}",
            findings.len(),
            root.display(),
            if findings.is_empty() { " — determinism contract holds" } else { "" },
        );
    }
    if args.flag("deny") && !findings.is_empty() {
        return Err(format!("audit --deny: {} finding(s)", findings.len()));
    }
    Ok(())
}

fn cmd_info() -> Result<(), String> {
    let p = NetParams::sarteco25();
    println!("== calibration (NetParams::sarteco25) ==");
    println!(
        "  inter-node: alpha={:.2}us, bw={:.2} GB/s (effective)",
        p.alpha_inter * 1e6,
        1e-9 / p.beta_inter
    );
    println!(
        "  intra-node: alpha={:.2}us, bw={:.2} GB/s",
        p.alpha_intra * 1e6,
        1e-9 / p.beta_intra
    );
    println!("  eager threshold: {}", fmt_bytes(p.eager_threshold));
    println!(
        "  registration: {:.2} GB/s, win setup {:.1}us",
        1e-9 / p.beta_register,
        p.win_setup * 1e6
    );
    println!("  progress chunk: {}", fmt_bytes(p.progress_chunk));
    println!(
        "  MT penalties: coll x{}, rma x{}; oversub x{}",
        p.mt_coll_penalty, p.mt_rma_penalty, p.oversub_factor
    );
    let sam = proteo::sam::SamConfig::sarteco25();
    println!("== workload (SamConfig::sarteco25) ==");
    println!(
        "  CSR: vals={} cols={} rowptr={} (total {})",
        sam.matrix_elems,
        sam.colind_elems,
        sam.rowptr_elems,
        fmt_bytes(sam.total_bytes())
    );
    println!(
        "  T_it(20)={} T_it(160)={}",
        fmt_seconds(sam.iter_compute(20)),
        fmt_seconds(sam.iter_compute(160))
    );
    match proteo::runtime::Manifest::load(&artifacts_dir()) {
        Ok(m) => println!(
            "== artifacts ==\n  n={} grid={} blocks=({},{},{},{}) vmem/step={}",
            m.n,
            m.grid,
            m.nbr,
            m.k,
            m.br,
            m.bc,
            fmt_bytes(m.vmem_bytes_per_step)
        ),
        Err(e) => println!("== artifacts ==\n  not available: {e}"),
    }
    Ok(())
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cli = cli();
    let (cmd, args) = match cli.parse(&argv) {
        Ok(x) => x,
        Err(msg) => {
            eprintln!("{msg}");
            return ExitCode::from(2);
        }
    };
    let result = match cmd.name {
        "exp" => cmd_exp(&args),
        "run" => cmd_run(&args),
        "scenario" => cmd_scenario(&args),
        "chaos" => cmd_chaos(&args),
        "ablation" => cmd_ablation(&args),
        "cg" => cmd_cg(&args),
        "engine-stress" => cmd_engine_stress(&args),
        "bench-smoke" => cmd_bench_smoke(&args),
        "bench-compare" => cmd_bench_compare(&args),
        "bench-promote" => cmd_bench_promote(&args),
        "audit" => cmd_audit(&args),
        "info" => cmd_info(),
        _ => unreachable!(),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(msg) => {
            eprintln!("error: {msg}");
            ExitCode::FAILURE
        }
    }
}
