//! Deterministic discrete-event cluster simulator.
//!
//! The paper evaluates on an 8-node / 160-core InfiniBand cluster that
//! we do not have; `simcluster` is the substitute substrate (see
//! DESIGN.md §1).  Simulated processes ("activities") are pool-reused
//! OS threads running ordinary imperative Rust — the MaM
//! redistribution algorithms read exactly like the paper's pseudocode
//! — but they are *scheduled* by a central engine over a virtual
//! clock: an activity blocks whenever it performs a simulated action
//! (`advance`, `park`) and the engine resumes it at the right virtual
//! time.  Exactly one activity body runs at any instant, so runs are
//! fully deterministic and seed-stable.  Events live in a bucketed
//! calendar queue (bit-identical to the seed binary heap, which is
//! retained behind [`QueueKind::Heap`] for equivalence testing);
//! thread-less [`LiteStep`] state machines make million-activity
//! simulations routine; `run_until_idle`/`rollback_to` give the
//! planner incremental micro-probes.
//!
//! * [`engine`]  — the event loop, virtual clock and activity handoff.
//! * [`activity`] — the context handle simulated code runs against.
//! * [`faults`]  — deterministic seeded fault injection (`--faults`).

pub mod activity;
pub mod engine;
pub mod faults;

pub use activity::ActivityCtx;
pub use faults::{FaultPlan, FaultSpec};
pub use engine::{
    default_queue_kind, set_default_queue_kind, ActivityId, Engine, EngineError, EngineStats,
    LiteCtx, LiteStep, QueueKind, Time,
};
