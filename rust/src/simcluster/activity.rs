//! The context handle simulated code runs against.
//!
//! An [`ActivityCtx`] is the only way a simulated process interacts
//! with virtual time: `advance` models compute, `park`/`unpark_at`
//! build synchronization, and `spawn` creates new simulated processes
//! (used by MaM's dynamic process spawning).  All higher layers
//! (`simmpi`, `mam`, `sam`) are written against this handle.

use std::cell::Cell;
use std::rc::Rc;
use std::sync::Arc;

use super::engine::{ActivityId, Handoff, Request, Time};

/// Per-activity handle; cheap to clone *within* the owning activity.
/// Clones share the clock state (`Rc<Cell>`), so every handle of one
/// activity observes the same local time.
#[derive(Clone)]
pub struct ActivityCtx {
    id: ActivityId,
    handoff: Arc<Handoff>,
    now: Rc<Cell<Time>>,
    /// Time lease (§Perf-L3, see [`engine::Resume`]): local advances
    /// strictly below this instant need no engine handoff.
    lease: Rc<Cell<Time>>,
}

// The ctx (with its Rc cells) is moved into the activity thread once;
// clones never leave that thread.
unsafe impl Send for ActivityCtx {}

impl ActivityCtx {
    pub(crate) fn new(id: ActivityId, handoff: Arc<Handoff>) -> ActivityCtx {
        ActivityCtx {
            id,
            handoff,
            now: Rc::new(Cell::new(0.0)),
            lease: Rc::new(Cell::new(0.0)),
        }
    }

    pub(crate) fn set_now(&self, t: Time) {
        // Never move the local clock backwards: an engine resume can
        // carry an older instant after lease-based local advances
        // (e.g. a queued wake delivered at its original time); treat it
        // as a spurious wake at the activity's own present.
        if t > self.now.get() {
            self.now.set(t);
        }
    }

    pub(crate) fn set_lease(&self, t: Time) {
        self.lease.set(t);
    }

    fn resumed(&self, r: crate::simcluster::engine::Resume) {
        if r.reset {
            // First resume after an engine rollback: adopt the rewound
            // clock even though it moves the local time backwards.
            self.now.set(r.now);
        } else {
            self.set_now(r.now);
        }
        self.lease.set(r.lease);
    }

    /// This activity's id.
    pub fn id(&self) -> ActivityId {
        self.id
    }

    /// Current virtual time.
    pub fn now(&self) -> Time {
        self.now.get()
    }

    /// Model `dt` seconds of local work (or sleeping); resumes at
    /// `now + dt`.  Negative durations are clamped to zero.
    pub fn advance(&self, dt: Time) {
        self.advance_until(self.now.get() + dt.max(0.0));
    }

    /// Resume at the *absolute* virtual time `t` (no-op if in the past).
    pub fn advance_until(&self, t: Time) {
        let target = t.max(self.now.get());
        // Lease fast path: nothing else is scheduled before `target`,
        // so the advance is a pure local clock bump — no handoff.
        // Zero-length advances always go through the engine: callers
        // use `advance(0.0)` as an explicit yield point, and skipping
        // it locally would spin without making virtual progress.
        if target > self.now.get() && target < self.lease.get() {
            self.now.set(target);
            return;
        }
        let r = self.handoff.activity_yield(Request::AdvanceUntil(target));
        self.resumed(r);
    }

    /// Park until another activity calls [`ActivityCtx::unpark_at`] for
    /// this activity.  Spurious wakeups are possible by design —
    /// callers re-check their condition in a loop.
    pub fn park(&self) {
        let r = self.handoff.activity_yield(Request::Park);
        self.resumed(r);
    }

    /// Schedule a wakeup for `target` at absolute time `at` (clamped to
    /// now).  Never lost: if `target` is not parked yet the wake is
    /// queued and consumed by its next `park`.
    pub fn unpark_at(&self, target: ActivityId, at: Time) {
        let r = self.handoff.activity_yield(Request::Unpark { target, at });
        self.resumed(r);
    }

    /// Wake `target` "immediately" (at the current virtual time).
    pub fn unpark_now(&self, target: ActivityId) {
        self.unpark_at(target, self.now());
    }

    /// Schedule wakeups for many targets in one engine round-trip.
    /// Ordering is identical to calling [`ActivityCtx::unpark_at`] for
    /// each entry in order, but a collective release among N ranks
    /// costs one engine event plus an O(N) sweep instead of N heap
    /// operations.
    pub fn unpark_batch(&self, entries: Vec<(ActivityId, Time)>) {
        if entries.is_empty() {
            return;
        }
        let r = self.handoff.activity_yield(Request::UnparkBatch(entries));
        self.resumed(r);
    }

    /// Spawn a new activity starting at the current virtual time;
    /// returns its id.  Used for dynamically created MPI processes and
    /// the Threading strategy's auxiliary threads.
    pub fn spawn<F>(&self, label: impl Into<String>, body: F) -> ActivityId
    where
        F: FnOnce(ActivityCtx) + Send + 'static,
    {
        let r = self.handoff.activity_yield(Request::Spawn {
            label: label.into(),
            body: Box::new(body),
            at: self.now.get(),
        });
        self.resumed(r);
        ActivityId(r.reply)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcluster::Engine;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    #[test]
    fn now_tracks_advances() {
        let mut e = Engine::new();
        e.spawn_at(0.0, "t", |ctx| {
            assert_eq!(ctx.now(), 0.0);
            ctx.advance(0.25);
            assert_eq!(ctx.now(), 0.25);
            ctx.advance_until(1.0);
            assert_eq!(ctx.now(), 1.0);
            // advancing to the past clamps
            ctx.advance_until(0.5);
            assert_eq!(ctx.now(), 1.0);
            ctx.advance(-3.0);
            assert_eq!(ctx.now(), 1.0);
        });
        e.run().unwrap();
    }

    #[test]
    fn unpark_now_wakes_at_same_time() {
        let mut e = Engine::new();
        let seen = Arc::new(Mutex::new(Vec::new()));
        let s = seen.clone();
        let sleeper = e.spawn_at(0.0, "sleeper", move |ctx| {
            ctx.park();
            s.lock().unwrap().push(ctx.now());
        });
        e.spawn_at(0.0, "waker", move |ctx| {
            ctx.advance(3.0);
            ctx.unpark_now(sleeper);
        });
        e.run().unwrap();
        assert_eq!(*seen.lock().unwrap(), vec![3.0]);
    }

    #[test]
    fn spawned_child_starts_at_parent_time() {
        let mut e = Engine::new();
        let starts = Arc::new(AtomicUsize::new(0));
        let s = starts.clone();
        e.spawn_at(0.0, "parent", move |ctx| {
            ctx.advance(2.0);
            let s2 = s.clone();
            ctx.spawn("kid", move |kctx| {
                assert_eq!(kctx.now(), 2.0);
                s2.fetch_add(1, Ordering::SeqCst);
            });
            ctx.advance(1.0);
        });
        e.run().unwrap();
        assert_eq!(starts.load(Ordering::SeqCst), 1);
    }
}
