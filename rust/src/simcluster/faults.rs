//! Deterministic, seeded fault injection (`--faults`).
//!
//! Every resize simulated before PR 9 assumed a perfect cluster:
//! spawns always succeed, NICs never stall, notify counters never get
//! lost.  This module is the substrate half of the fault-tolerance
//! story: a [`FaultSpec`] (parsed from the `--faults` CLI grammar)
//! compiled into a [`FaultPlan`] whose every decision is a **pure
//! function of `(seed, decision keys)`** — no hidden stream state, no
//! draw-order coupling.  Two consequences fall out of that purity:
//!
//! * runs stay byte-deterministic per seed — injected faults are
//!   ordinary engine events at exact virtual times, replayed
//!   identically on every rerun;
//! * SPMD agreement is free — every rank evaluating the same decision
//!   keys (e.g. "is the notify counter of resize 20→160 lost?")
//!   computes the same answer locally, with no extra synchronization
//!   that would perturb the fault-free timing.
//!
//! Each decision hashes `(seed, tag, keys…)` through FNV-1a and seeds
//! a fresh xoshiro generator from the digest — adjacent keys give
//! statistically independent draws, and adding a new fault class never
//! shifts the draws of an existing one.
//!
//! Recovery policy (retry budgets, backoff, rollback) lives in
//! [`mam::resilience`](../mam/resilience/index.html); this module only
//! answers "does X fail?".

use crate::util::rng::Rng;

/// Decision-class tags (first FNV word, keeps classes independent).
const TAG_SPAWN: u64 = 0x5350_4157; // "SPAW"
const TAG_NOTIFY: u64 = 0x4e4f_5446; // "NOTF"
const TAG_STRAGGLER: u64 = 0x5354_5247; // "STRG"
const TAG_REG: u64 = 0x5245_4753; // "REGS"

/// Parsed `--faults` specification.  Grammar: comma-separated `k=v`
/// pairs (order-free), e.g.
///
/// ```text
/// seed=42,spawn=0.3,mode=rank,kind=hang,timeout=0.25,retries=2,
/// backoff=0.02,backoff-cap=0.16,reg=0.1x4,notify=0.2,straggler=0.1@0.05
/// ```
///
/// * `seed=<u64>` — decision seed (default 42).
/// * `spawn=<p|firstK>` — spawn-failure probability in `[0,1]`, or the
///   deterministic form `firstK`: the first `K` attempts of every
///   spawn fail outright (what the acceptance test uses).
/// * `mode=wave|rank` — whole-wave failures vs independent per-rank
///   failures (Async re-dispatches only the failed subset).
/// * `kind=fast|hang` — failed spawns report immediately vs hang until
///   `timeout=<secs>` expires.
/// * `retries=<n>`, `backoff=<secs>`, `backoff-cap=<secs>` — recovery
///   budget: capped exponential backoff between attempts.
/// * `reg=<p>x<factor>` — each source's registration runs `factor`×
///   slower with probability `p` (NIC pinning stall).
/// * `notify=<p>` (+ `notify-timeout=<secs>`) — the notify counters of
///   a resize are lost with probability `p`; ranks time out and fall
///   back to epoch sync.
/// * `straggler=<p>@<max>` — each source rank enters the resize up to
///   `max` seconds late with probability `p`.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    pub seed: u64,
    /// Probability a spawn attempt fails (per wave, or per rank under
    /// `mode=rank`).  Ignored when `spawn_fail_first > 0`.
    pub spawn_fail_p: f64,
    /// Deterministic mode: the first K attempts of every spawn fail
    /// (0 = probabilistic via `spawn_fail_p`).
    pub spawn_fail_first: u32,
    /// Per-rank failures instead of whole-wave.
    pub per_rank: bool,
    /// Failed spawns hang until `hang_timeout` instead of failing fast.
    pub hang: bool,
    /// Detection latency of a hung spawn attempt.
    pub hang_timeout: f64,
    /// Retry budget per spawn phase (attempts = 1 + retries).
    pub retries: u32,
    /// Initial backoff before a retry; doubles per attempt.
    pub backoff: f64,
    /// Backoff ceiling.
    pub backoff_cap: f64,
    /// Probability a source's registration segment stream is slowed.
    pub reg_slow_p: f64,
    /// Stretch factor of a slowed registration (≥ 1).
    pub reg_slow_factor: f64,
    /// Probability the notify counters of a resize are lost
    /// (`--rma-sync notify` falls back to epoch sync after a timeout).
    pub notify_loss_p: f64,
    /// Detection latency of lost notify counters.
    pub notify_timeout: f64,
    /// Probability a source rank straggles into the resize.
    pub straggler_p: f64,
    /// Maximum straggler delay (uniform in `(0, max]`).
    pub straggler_max: f64,
}

impl Default for FaultSpec {
    fn default() -> FaultSpec {
        FaultSpec {
            seed: 42,
            spawn_fail_p: 0.0,
            spawn_fail_first: 0,
            per_rank: false,
            hang: false,
            hang_timeout: 0.25,
            retries: 2,
            backoff: 0.02,
            backoff_cap: 0.16,
            reg_slow_p: 0.0,
            reg_slow_factor: 4.0,
            notify_loss_p: 0.0,
            notify_timeout: 0.2,
            straggler_p: 0.0,
            straggler_max: 0.1,
        }
    }
}

fn parse_prob(key: &str, v: &str) -> Result<f64, String> {
    let p: f64 = v.parse().map_err(|_| format!("--faults: bad {key}={v}"))?;
    if !(0.0..=1.0).contains(&p) {
        return Err(format!("--faults: {key}={v} outside [0,1]"));
    }
    Ok(p)
}

fn parse_secs(key: &str, v: &str) -> Result<f64, String> {
    let s: f64 = v.parse().map_err(|_| format!("--faults: bad {key}={v}"))?;
    if !s.is_finite() || s < 0.0 {
        return Err(format!("--faults: {key}={v} must be >= 0"));
    }
    Ok(s)
}

impl FaultSpec {
    /// Parse the comma-separated `k=v` grammar (see type docs).
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        let mut spec = FaultSpec::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| format!("--faults: expected k=v, got '{part}'"))?;
            match k {
                "seed" => {
                    spec.seed =
                        v.parse().map_err(|_| format!("--faults: bad seed={v}"))?;
                }
                "spawn" => {
                    if let Some(kk) = v.strip_prefix("first") {
                        spec.spawn_fail_first = kk
                            .parse()
                            .map_err(|_| format!("--faults: bad spawn={v}"))?;
                        spec.spawn_fail_p = 0.0;
                    } else {
                        spec.spawn_fail_p = parse_prob("spawn", v)?;
                        spec.spawn_fail_first = 0;
                    }
                }
                "mode" => match v {
                    "wave" => spec.per_rank = false,
                    "rank" => spec.per_rank = true,
                    _ => return Err(format!("--faults: mode={v} (wave|rank)")),
                },
                "kind" => match v {
                    "fast" => spec.hang = false,
                    "hang" => spec.hang = true,
                    _ => return Err(format!("--faults: kind={v} (fast|hang)")),
                },
                "timeout" => spec.hang_timeout = parse_secs("timeout", v)?,
                "retries" => {
                    spec.retries =
                        v.parse().map_err(|_| format!("--faults: bad retries={v}"))?;
                }
                "backoff" => spec.backoff = parse_secs("backoff", v)?,
                "backoff-cap" => spec.backoff_cap = parse_secs("backoff-cap", v)?,
                "reg" => {
                    let (p, f) = v
                        .split_once('x')
                        .ok_or_else(|| format!("--faults: reg={v} (want <p>x<factor>)"))?;
                    spec.reg_slow_p = parse_prob("reg", p)?;
                    spec.reg_slow_factor =
                        f.parse().map_err(|_| format!("--faults: bad reg factor {f}"))?;
                    if !(spec.reg_slow_factor >= 1.0) {
                        return Err(format!("--faults: reg factor {f} must be >= 1"));
                    }
                }
                "notify" => spec.notify_loss_p = parse_prob("notify", v)?,
                "notify-timeout" => spec.notify_timeout = parse_secs("notify-timeout", v)?,
                "straggler" => {
                    let (p, d) = v
                        .split_once('@')
                        .ok_or_else(|| format!("--faults: straggler={v} (want <p>@<max>)"))?;
                    spec.straggler_p = parse_prob("straggler", p)?;
                    spec.straggler_max = parse_secs("straggler", d)?;
                }
                _ => return Err(format!("--faults: unknown key '{k}'")),
            }
        }
        Ok(spec)
    }

    /// Does this spec inject anything at all?  Inactive specs must
    /// leave every simulated timing bit-identical to a run with no
    /// spec installed.
    pub fn is_active(&self) -> bool {
        self.spawn_fail_p > 0.0
            || self.spawn_fail_first > 0
            || self.reg_slow_p > 0.0
            || self.notify_loss_p > 0.0
            || self.straggler_p > 0.0
    }

    /// Canonical spec string (parse ∘ to_spec_string is identity on
    /// the fields; used by provenance JSON).
    pub fn to_spec_string(&self) -> String {
        let spawn = if self.spawn_fail_first > 0 {
            format!("first{}", self.spawn_fail_first)
        } else {
            format!("{}", self.spawn_fail_p)
        };
        format!(
            "seed={},spawn={},mode={},kind={},timeout={},retries={},backoff={},\
             backoff-cap={},reg={}x{},notify={},notify-timeout={},straggler={}@{}",
            self.seed,
            spawn,
            if self.per_rank { "rank" } else { "wave" },
            if self.hang { "hang" } else { "fast" },
            self.hang_timeout,
            self.retries,
            self.backoff,
            self.backoff_cap,
            self.reg_slow_p,
            self.reg_slow_factor,
            self.notify_loss_p,
            self.notify_timeout,
            self.straggler_p,
            self.straggler_max,
        )
    }
}

/// Compiled fault plan: the spec plus its keyed decision functions.
/// Immutable and shared (`Arc<FaultPlan>` lives in the `MpiWorld`);
/// deliberately *not* part of world snapshots — it is configuration,
/// not simulation state.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub spec: FaultSpec,
}

impl FaultPlan {
    pub fn new(spec: FaultSpec) -> FaultPlan {
        FaultPlan { spec }
    }

    /// Seed a fresh generator from `(seed, tag, keys…)` via FNV-1a.
    /// Fresh per decision: no draw-order coupling between decisions.
    fn draw(&self, tag: u64, keys: &[u64]) -> Rng {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325 ^ self.spec.seed;
        for v in std::iter::once(tag).chain(keys.iter().copied()) {
            for b in v.to_le_bytes() {
                h ^= u64::from(b);
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        Rng::new(h)
    }

    /// Number of spawned ranks failing on `attempt` (0-based) of the
    /// spawn keyed `(resize, dispatch)`.  Wave mode fails all or none;
    /// rank mode draws each of the `n_new` ranks independently.
    ///
    /// `spawn=firstK` counts attempts *cumulatively across dispatches
    /// of the same resize*: a re-queued resize that already burned its
    /// retry budget (retries + 1 attempts per dispatch) resumes the
    /// count where the aborted dispatch left it, so `first3` with
    /// `retries=2` aborts dispatch 0 and succeeds on dispatch 1 —
    /// exactly the abort-then-recover trace the rollback tests need.
    pub fn spawn_failures(
        &self,
        resize: u64,
        dispatch: u64,
        attempt: u32,
        n_new: usize,
    ) -> usize {
        if n_new == 0 {
            return 0;
        }
        if self.spec.spawn_fail_first > 0 {
            let per_dispatch = u64::from(self.spec.retries) + 1;
            let global = dispatch
                .saturating_mul(per_dispatch)
                .saturating_add(u64::from(attempt));
            return if global < u64::from(self.spec.spawn_fail_first) { n_new } else { 0 };
        }
        if self.spec.spawn_fail_p <= 0.0 {
            return 0;
        }
        let mut rng = self.draw(TAG_SPAWN, &[resize, dispatch, u64::from(attempt)]);
        if self.spec.per_rank {
            (0..n_new).filter(|_| rng.gen_bool(self.spec.spawn_fail_p)).count()
        } else if rng.gen_bool(self.spec.spawn_fail_p) {
            n_new
        } else {
            0
        }
    }

    /// Are the notify counters of the `ns → nd` redistribution lost?
    /// Keyed by the shape only, so sources and (independently spawned)
    /// drains agree on the epoch-sync fallback without communicating.
    pub fn notify_lost(&self, ns: usize, nd: usize) -> bool {
        if self.spec.notify_loss_p <= 0.0 {
            return false;
        }
        self.draw(TAG_NOTIFY, &[ns as u64, nd as u64])
            .gen_bool(self.spec.notify_loss_p)
    }

    /// Straggler delay of `rank` entering the resize (0.0 = on time).
    pub fn straggler_delay(&self, resize: u64, dispatch: u64, rank: usize) -> f64 {
        if self.spec.straggler_p <= 0.0 || self.spec.straggler_max <= 0.0 {
            return 0.0;
        }
        let mut rng = self.draw(TAG_STRAGGLER, &[resize, dispatch, rank as u64]);
        if rng.gen_bool(self.spec.straggler_p) {
            rng.gen_range_f64(0.0, self.spec.straggler_max).max(f64::MIN_POSITIVE)
        } else {
            0.0
        }
    }

    /// Registration stretch factor of `rank`'s segment stream for this
    /// resize (1.0 = healthy NIC).
    pub fn reg_slow_factor(&self, resize: u64, dispatch: u64, rank: usize) -> f64 {
        if self.spec.reg_slow_p <= 0.0 {
            return 1.0;
        }
        let mut rng = self.draw(TAG_REG, &[resize, dispatch, rank as u64]);
        if rng.gen_bool(self.spec.reg_slow_p) {
            self.spec.reg_slow_factor
        } else {
            1.0
        }
    }

    /// Virtual seconds before a failed attempt is *detected*: fail-fast
    /// reports at `base` (the strategy-dependent launch latency), a
    /// hang is only noticed when the timeout expires.
    pub fn detect_latency(&self, base: f64) -> f64 {
        if self.spec.hang {
            self.spec.hang_timeout.max(base)
        } else {
            base
        }
    }

    /// Backoff before retry attempt `attempt` (1-based): capped
    /// exponential, `backoff · 2^(attempt-1)` clamped to the cap.
    pub fn backoff_before(&self, attempt: u32) -> f64 {
        let exp = 2f64.powi(attempt.saturating_sub(1).min(30) as i32);
        (self.spec.backoff * exp).min(self.spec.backoff_cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_through_the_canonical_string() {
        let s = FaultSpec::parse(
            "seed=7,spawn=0.3,mode=rank,kind=hang,timeout=0.5,retries=3,\
             backoff=0.01,backoff-cap=0.08,reg=0.1x4,notify=0.2,\
             notify-timeout=0.3,straggler=0.15@0.05",
        )
        .unwrap();
        assert_eq!(s.seed, 7);
        assert!((s.spawn_fail_p - 0.3).abs() < 1e-12);
        assert!(s.per_rank && s.hang);
        assert_eq!(s.retries, 3);
        assert_eq!(FaultSpec::parse(&s.to_spec_string()).unwrap(), s);
    }

    #[test]
    fn parse_first_k_and_defaults() {
        let s = FaultSpec::parse("spawn=first2").unwrap();
        assert_eq!(s.spawn_fail_first, 2);
        assert_eq!(s.spawn_fail_p, 0.0);
        assert_eq!(s.seed, 42);
        assert!(s.is_active());
        assert!(!FaultSpec::default().is_active());
        assert_eq!(FaultSpec::parse("").unwrap(), FaultSpec::default());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(FaultSpec::parse("spawn").is_err());
        assert!(FaultSpec::parse("spawn=1.5").is_err());
        assert!(FaultSpec::parse("mode=sideways").is_err());
        assert!(FaultSpec::parse("reg=0.5").is_err());
        assert!(FaultSpec::parse("reg=0.5x0.5").is_err());
        assert!(FaultSpec::parse("straggler=0.5").is_err());
        assert!(FaultSpec::parse("warp=9").is_err());
        assert!(FaultSpec::parse("timeout=-1").is_err());
    }

    #[test]
    fn decisions_are_pure_functions_of_seed_and_keys() {
        let p = FaultPlan::new(
            FaultSpec::parse("seed=5,spawn=0.5,reg=0.5x4,notify=0.5,straggler=0.5@0.1").unwrap(),
        );
        for _ in 0..3 {
            assert_eq!(p.spawn_failures(1, 0, 0, 8), p.spawn_failures(1, 0, 0, 8));
            assert_eq!(p.notify_lost(20, 160), p.notify_lost(20, 160));
            assert_eq!(
                p.straggler_delay(2, 1, 3).to_bits(),
                p.straggler_delay(2, 1, 3).to_bits()
            );
            assert_eq!(
                p.reg_slow_factor(2, 1, 3).to_bits(),
                p.reg_slow_factor(2, 1, 3).to_bits()
            );
        }
        // Different seeds decide differently somewhere.
        let q = FaultPlan::new(FaultSpec::parse("seed=6,spawn=0.5").unwrap());
        let diverge = (0..64)
            .any(|a| p.spawn_failures(a, 0, 0, 1) != q.spawn_failures(a, 0, 0, 1));
        assert!(diverge);
    }

    #[test]
    fn inactive_plan_injects_nothing() {
        let p = FaultPlan::new(FaultSpec::default());
        for r in 0..32 {
            assert_eq!(p.spawn_failures(r, 0, 0, 16), 0);
            assert_eq!(p.straggler_delay(r, 0, 0), 0.0);
            assert_eq!(p.reg_slow_factor(r, 0, 0), 1.0);
        }
        assert!(!p.notify_lost(20, 160));
    }

    #[test]
    fn first_k_fails_exactly_the_first_k_attempts() {
        let p = FaultPlan::new(FaultSpec::parse("spawn=first2").unwrap());
        assert_eq!(p.spawn_failures(0, 0, 0, 4), 4);
        assert_eq!(p.spawn_failures(0, 0, 1, 4), 4);
        assert_eq!(p.spawn_failures(0, 0, 2, 4), 0);
        assert_eq!(p.spawn_failures(9, 0, 0, 4), 4, "every resize's first dispatch");
        // A re-dispatch resumes the cumulative attempt count: with the
        // default retries=2 a dispatch burns 3 attempts, so dispatch 1
        // starts at global attempt 3 — past first2, all healthy.
        assert_eq!(p.spawn_failures(0, 1, 0, 4), 0);
        // first3 + retries=2: dispatch 0 exhausts (attempts 0..=2 all
        // fail, abort), dispatch 1 recovers immediately.
        let q = FaultPlan::new(FaultSpec::parse("spawn=first3").unwrap());
        assert_eq!(q.spawn_failures(0, 0, 2, 4), 4);
        assert_eq!(q.spawn_failures(0, 1, 0, 4), 0);
    }

    #[test]
    fn wave_mode_is_all_or_none_rank_mode_is_a_subset() {
        let wave = FaultPlan::new(FaultSpec::parse("spawn=0.5,mode=wave").unwrap());
        for r in 0..32 {
            let f = wave.spawn_failures(r, 0, 0, 8);
            assert!(f == 0 || f == 8, "wave failure must be whole-wave, got {f}");
        }
        let rank = FaultPlan::new(FaultSpec::parse("spawn=0.5,mode=rank").unwrap());
        let counts: Vec<usize> = (0..32).map(|r| rank.spawn_failures(r, 0, 0, 8)).collect();
        assert!(counts.iter().all(|&f| f <= 8));
        assert!(counts.iter().any(|&f| f > 0 && f < 8), "partial waves expected");
    }

    #[test]
    fn backoff_is_capped_exponential_and_hang_extends_detection() {
        let p = FaultPlan::new(
            FaultSpec::parse("kind=hang,timeout=0.5,backoff=0.02,backoff-cap=0.05").unwrap(),
        );
        assert!((p.backoff_before(1) - 0.02).abs() < 1e-12);
        assert!((p.backoff_before(2) - 0.04).abs() < 1e-12);
        assert!((p.backoff_before(3) - 0.05).abs() < 1e-12, "capped");
        assert!((p.backoff_before(9) - 0.05).abs() < 1e-12);
        assert_eq!(p.detect_latency(0.1), 0.5);
        assert_eq!(p.detect_latency(0.9), 0.9, "slow launch dominates the timeout");
        let fast = FaultPlan::new(FaultSpec::default());
        assert_eq!(fast.detect_latency(0.1), 0.1);
    }
}
