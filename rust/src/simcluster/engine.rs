//! The discrete-event engine: virtual clock, event heap, and the
//! thread handoff protocol that suspends/resumes simulated activities.
//!
//! ## Handoff protocol
//!
//! Every activity owns a [`Handoff`] slot (mutex + condvar).  The
//! engine resumes an activity by storing `ToActivity` and waits for the
//! slot to flip back to `ToEngine(request)`; the activity does the
//! mirror image.  This gives strict alternation — at most one activity
//! body executes at a time — which is what makes simulation runs
//! deterministic regardless of OS scheduling.
//!
//! ## Wakeups
//!
//! `park`/`unpark` use counting semantics (a pending-wake queue per
//! activity), so an `unpark` that is issued *before* the target parks
//! is never lost.  Higher layers are written condition-variable style:
//! `while !condition { ctx.park(); }` — spurious wakeups are allowed
//! and harmless.

use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::activity::ActivityCtx;

/// Virtual time in seconds.
pub type Time = f64;

/// Identifier of a simulated activity (process or auxiliary thread).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ActivityId(pub usize);

/// Errors surfaced by [`Engine::run`].
#[derive(Debug)]
pub enum EngineError {
    Deadlock { time: Time, parked: usize, detail: String },
    ActivityPanic(ActivityId, String, String),
    EventLimit(u64),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::Deadlock { time, parked, detail } => write!(
                f,
                "deadlock at t={time:.9}s: {parked} activities parked, no pending events: {detail}"
            ),
            EngineError::ActivityPanic(id, label, msg) => {
                write!(f, "activity {id:?} ({label}) panicked: {msg}")
            }
            EngineError::EventLimit(n) => write!(f, "event limit of {n} exceeded (livelock guard)"),
        }
    }
}

impl std::error::Error for EngineError {}

/// What an activity asks the engine to do when it yields.
pub(crate) enum Request {
    /// Resume me at absolute virtual time `t` (compute / sleep).
    AdvanceUntil(Time),
    /// Park until some other activity unparks me.
    Park,
    /// Schedule a wakeup for `target` at absolute time `at`, then
    /// continue running me immediately.
    Unpark { target: ActivityId, at: Time },
    /// Spawn a new activity starting at `at` (the caller's local time,
    /// which may be ahead of the engine clock under a lease); reply
    /// with its id, continue me immediately.
    Spawn { label: String, body: BodyFn, at: Time },
    /// Activity body finished (normally or by panic) at local time `at`.
    Exit { panic_msg: Option<String>, at: Time },
}

pub(crate) type BodyFn = Box<dyn FnOnce(ActivityCtx) + Send + 'static>;

/// Value the engine passes back when it resumes an activity.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Resume {
    /// Current virtual time.
    pub now: Time,
    /// Reply value (spawn returns the new ActivityId here).
    pub reply: usize,
    /// §Perf-L3 time lease: the activity may advance its local clock up
    /// to (strictly below) this instant WITHOUT a handoff — no other
    /// event precedes it, and since exactly one activity runs at a
    /// time, none can appear.  The engine↔activity thread ping-pong
    /// (~5–10 µs of futex traffic per simulated call) is the DES's
    /// dominant cost; leases remove it for every compute segment that
    /// fits before the next scheduled event.
    pub lease: Time,
}

pub(crate) enum Slot {
    Empty,
    ToActivity(Resume),
    ToEngine(Request),
}

/// One mutex+condvar pair per activity; both sides block on it.
pub(crate) struct Handoff {
    pub slot: Mutex<Slot>,
    pub cv: Condvar,
}

impl Handoff {
    fn new() -> Arc<Handoff> {
        Arc::new(Handoff { slot: Mutex::new(Slot::Empty), cv: Condvar::new() })
    }

    /// Engine side: hand control to the activity and wait for its next
    /// request.
    fn engine_step(&self, resume: Resume) -> Request {
        let mut slot = self.slot.lock().unwrap();
        *slot = Slot::ToActivity(resume);
        self.cv.notify_all();
        loop {
            match std::mem::replace(&mut *slot, Slot::Empty) {
                Slot::ToEngine(req) => return req,
                other => {
                    *slot = other;
                    slot = self.cv.wait(slot).unwrap();
                }
            }
        }
    }

    /// Activity side: submit a request and wait to be resumed.
    pub(crate) fn activity_yield(&self, req: Request) -> Resume {
        let mut slot = self.slot.lock().unwrap();
        *slot = Slot::ToEngine(req);
        self.cv.notify_all();
        loop {
            match std::mem::replace(&mut *slot, Slot::Empty) {
                Slot::ToActivity(r) => return r,
                other => {
                    *slot = other;
                    slot = self.cv.wait(slot).unwrap();
                }
            }
        }
    }

    /// Activity side: final request (Exit) — posts without waiting for
    /// a resume, so the thread can return and be joined by the engine.
    fn activity_finish(&self, req: Request) {
        let mut slot = self.slot.lock().unwrap();
        *slot = Slot::ToEngine(req);
        self.cv.notify_all();
    }

    /// Activity side: first wait (thread start) — no request submitted.
    fn activity_wait_first(&self) -> Resume {
        let mut slot = self.slot.lock().unwrap();
        loop {
            match std::mem::replace(&mut *slot, Slot::Empty) {
                Slot::ToActivity(r) => return r,
                other => {
                    *slot = other;
                    slot = self.cv.wait(slot).unwrap();
                }
            }
        }
    }
}

/// Heap event: resume `activity` at `time`.  `seq` breaks ties FIFO so
/// equal-time events are processed in insertion order (determinism).
struct Event {
    time: Time,
    seq: u64,
    activity: ActivityId,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

struct ActivityState {
    label: String,
    handoff: Arc<Handoff>,
    join: Option<std::thread::JoinHandle<()>>,
    /// Wakeups delivered while the activity was not parked.
    pending_wakes: VecDeque<Time>,
    parked: bool,
    done: bool,
}

/// Shared counters the [`ActivityCtx`] can read without a handoff.
pub(crate) struct EngineShared {
    /// Monotone count of processed events — cheap progress metric.
    pub events_processed: AtomicU64,
}

/// The discrete-event engine.
pub struct Engine {
    heap: BinaryHeap<Event>,
    seq: u64,
    clock: Time,
    activities: HashMap<ActivityId, ActivityState>,
    next_id: usize,
    alive: usize,
    pub(crate) shared: Arc<EngineShared>,
    /// Livelock guard; configurable via [`Engine::set_event_limit`].
    event_limit: u64,
    /// Reused scratch for deadlock detection (parked-activity ids) —
    /// no per-detection allocation.
    parked_scratch: Vec<ActivityId>,
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine {
    pub fn new() -> Engine {
        Engine {
            heap: BinaryHeap::new(),
            seq: 0,
            clock: 0.0,
            activities: HashMap::new(),
            next_id: 0,
            alive: 0,
            shared: Arc::new(EngineShared { events_processed: AtomicU64::new(0) }),
            event_limit: 500_000_000,
            parked_scratch: Vec::new(),
        }
    }

    /// Lower the livelock guard (useful in tests).
    pub fn set_event_limit(&mut self, limit: u64) {
        self.event_limit = limit;
    }

    /// Current virtual time (valid between `run` calls or after run).
    pub fn now(&self) -> Time {
        self.clock
    }

    /// Total events processed so far (simulator throughput metric).
    pub fn events_processed(&self) -> u64 {
        self.shared.events_processed.load(Ordering::Relaxed)
    }

    fn push_event(&mut self, time: Time, activity: ActivityId) {
        self.seq += 1;
        self.heap.push(Event { time, seq: self.seq, activity });
    }

    /// Register an activity to start at virtual time `start`.
    pub fn spawn_at<F>(&mut self, start: Time, label: impl Into<String>, body: F) -> ActivityId
    where
        F: FnOnce(ActivityCtx) + Send + 'static,
    {
        let id = self.spawn_suspended(label, Box::new(body));
        self.push_event(start, id);
        id
    }

    /// Create the activity thread without scheduling it.
    fn spawn_suspended(&mut self, label: impl Into<String>, body: BodyFn) -> ActivityId {
        let id = ActivityId(self.next_id);
        self.next_id += 1;
        let label = label.into();
        let handoff = Handoff::new();
        let ctx = ActivityCtx::new(id, handoff.clone());
        let thread_label = label.clone();
        let h2 = handoff.clone();
        let join = std::thread::Builder::new()
            .name(format!("sim-{thread_label}"))
            .stack_size(1 << 20)
            .spawn(move || {
                let first = h2.activity_wait_first();
                ctx.set_now(first.now);
                ctx.set_lease(first.lease);
                let ctx2 = ctx.clone();
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    body(ctx);
                }));
                let panic_msg = result.err().map(|e| {
                    e.downcast_ref::<String>()
                        .cloned()
                        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "<non-string panic>".to_string())
                });
                // Final post: do not wait for a resume — the engine
                // joins this thread right after handling Exit.  Carry
                // the final local time so lease-advanced clocks are
                // reflected in the engine clock.
                h2.activity_finish(Request::Exit { panic_msg, at: ctx2.now() });
            })
            .expect("spawn simulation thread");
        self.activities.insert(
            id,
            ActivityState {
                label,
                handoff,
                join: Some(join),
                pending_wakes: VecDeque::new(),
                parked: false,
                done: false,
            },
        );
        self.alive += 1;
        id
    }

    /// Drive the simulation until every activity has finished.
    pub fn run(&mut self) -> Result<Time, EngineError> {
        let result = self.run_inner();
        // On error, detach remaining threads so we don't hang on drop:
        // they are parked forever; marking done lets Drop skip joins.
        if result.is_err() {
            for st in self.activities.values_mut() {
                st.done = true;
                st.join = None; // detach
            }
            self.alive = 0;
        }
        result
    }

    fn run_inner(&mut self) -> Result<Time, EngineError> {
        let mut processed: u64 = 0;
        while self.alive > 0 {
            let Some(ev) = self.heap.pop() else {
                // Collect parked ids into the reusable scratch (no
                // per-detection allocation; sorted so the report is
                // deterministic despite HashMap iteration order).
                let mut scratch = std::mem::take(&mut self.parked_scratch);
                scratch.clear();
                scratch.extend(
                    self.activities
                        .iter()
                        .filter(|(_, a)| a.parked && !a.done)
                        .map(|(id, _)| *id),
                );
                scratch.sort();
                let detail = scratch
                    .iter()
                    .map(|id| self.activities[id].label.as_str())
                    .collect::<Vec<_>>()
                    .join(", ");
                let parked = scratch.len();
                self.parked_scratch = scratch;
                return Err(EngineError::Deadlock { time: self.clock, parked, detail });
            };
            processed += 1;
            if processed > self.event_limit {
                return Err(EngineError::EventLimit(self.event_limit));
            }
            debug_assert!(ev.time >= self.clock - 1e-12, "time went backwards");
            self.clock = self.clock.max(ev.time);
            let current = ev.activity;
            let mut reply: usize = 0;
            // Run the activity; immediate requests (Unpark/Spawn) keep
            // control in the same activity without a heap round-trip.
            loop {
                let lease = self.heap.peek().map_or(f64::INFINITY, |e| e.time);
                // §Perf: the handoff is borrowed for the step instead of
                // Arc-cloned per resume — the engine thread blocks inside
                // `engine_step`, nothing touches the activity table
                // meanwhile, and the request is handled after the borrow
                // ends.
                let req = match self.activities.get_mut(&current) {
                    Some(st) if !st.done => {
                        st.parked = false;
                        st.handoff.engine_step(Resume { now: self.clock, reply, lease })
                    }
                    _ => break, // stale event for a finished activity
                };
                self.shared.events_processed.fetch_add(1, Ordering::Relaxed);
                reply = 0;
                match req {
                    Request::AdvanceUntil(t) => {
                        let t = t.max(self.clock);
                        self.push_event(t, current);
                        break;
                    }
                    Request::Park => {
                        let st = self.activities.get_mut(&current).unwrap();
                        if let Some(at) = st.pending_wakes.pop_front() {
                            // A wake was already queued: resume at its
                            // delivery time (>= now by construction).
                            let t = at.max(self.clock);
                            self.push_event(t, current);
                        } else {
                            st.parked = true;
                        }
                        break;
                    }
                    Request::Unpark { target, at } => {
                        let at = at.max(self.clock);
                        if let Some(tst) = self.activities.get_mut(&target) {
                            if tst.done {
                                // waking a finished activity is a no-op
                            } else if tst.parked {
                                tst.parked = false;
                                self.push_event(at, target);
                            } else {
                                tst.pending_wakes.push_back(at);
                            }
                        }
                        // fall through: continue the same activity now
                    }
                    Request::Spawn { label, body, at } => {
                        let new_id = self.spawn_suspended(label, body);
                        self.push_event(at.max(self.clock), new_id);
                        reply = new_id.0;
                        // continue the same activity, replying the id
                    }
                    Request::Exit { panic_msg, at } => {
                        self.clock = self.clock.max(at);
                        let st = self.activities.get_mut(&current).unwrap();
                        st.done = true;
                        st.parked = false;
                        // The activity is done: move the label out
                        // instead of cloning (it is only needed for the
                        // panic report; done activities never appear in
                        // deadlock details).
                        let label = std::mem::take(&mut st.label);
                        if let Some(j) = st.join.take() {
                            let _ = j.join();
                        }
                        self.alive -= 1;
                        if let Some(msg) = panic_msg {
                            return Err(EngineError::ActivityPanic(current, label, msg));
                        }
                        break;
                    }
                }
            }
        }
        Ok(self.clock)
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // Any threads still alive are parked in their handoff; they hold
        // no engine locks, so leaking them on abnormal paths is safe.
        for st in self.activities.values_mut() {
            if let Some(j) = st.join.take() {
                if st.done {
                    let _ = j.join();
                } // else: detached
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering as O};

    #[test]
    fn single_activity_advances_clock() {
        let mut e = Engine::new();
        let log = Arc::new(Mutex::new(Vec::new()));
        let l2 = log.clone();
        e.spawn_at(0.0, "a", move |ctx| {
            ctx.advance(1.5);
            l2.lock().unwrap().push(ctx.now());
            ctx.advance(0.5);
            l2.lock().unwrap().push(ctx.now());
        });
        let end = e.run().unwrap();
        assert!((end - 2.0).abs() < 1e-12);
        assert_eq!(*log.lock().unwrap(), vec![1.5, 2.0]);
    }

    #[test]
    fn two_activities_interleave_by_time() {
        let mut e = Engine::new();
        let log: Arc<Mutex<Vec<(&str, Time)>>> = Arc::new(Mutex::new(Vec::new()));
        let (la, lb) = (log.clone(), log.clone());
        e.spawn_at(0.0, "a", move |ctx| {
            ctx.advance(1.0);
            la.lock().unwrap().push(("a", ctx.now()));
            ctx.advance(2.0);
            la.lock().unwrap().push(("a", ctx.now()));
        });
        e.spawn_at(0.0, "b", move |ctx| {
            ctx.advance(2.0);
            lb.lock().unwrap().push(("b", ctx.now()));
        });
        e.run().unwrap();
        assert_eq!(
            *log.lock().unwrap(),
            vec![("a", 1.0), ("b", 2.0), ("a", 3.0)]
        );
    }

    #[test]
    fn park_unpark_roundtrip() {
        let mut e = Engine::new();
        let flag = Arc::new(AtomicUsize::new(0));
        let f2 = flag.clone();
        let waiter = e.spawn_at(0.0, "waiter", move |ctx| {
            ctx.park();
            f2.store(1, O::SeqCst);
            assert!((ctx.now() - 5.0).abs() < 1e-12);
        });
        e.spawn_at(0.0, "waker", move |ctx| {
            ctx.advance(2.0);
            ctx.unpark_at(waiter, 5.0);
        });
        e.run().unwrap();
        assert_eq!(flag.load(O::SeqCst), 1);
    }

    #[test]
    fn unpark_before_park_is_not_lost() {
        let mut e = Engine::new();
        let waiter = e.spawn_at(0.0, "late-parker", move |ctx| {
            // Do a long compute first; the wake arrives "during" it.
            ctx.advance(10.0);
            ctx.park(); // must complete because wake was queued
            assert!(ctx.now() >= 10.0);
        });
        e.spawn_at(0.0, "early-waker", move |ctx| {
            ctx.unpark_at(waiter, 1.0);
        });
        e.run().unwrap();
    }

    #[test]
    fn deadlock_is_detected() {
        let mut e = Engine::new();
        e.spawn_at(0.0, "stuck", |ctx| {
            ctx.park();
        });
        match e.run() {
            Err(EngineError::Deadlock { parked, detail, .. }) => {
                assert_eq!(parked, 1);
                assert!(detail.contains("stuck"));
            }
            other => panic!("expected deadlock, got {other:?}"),
        }
    }

    #[test]
    fn activity_panic_is_propagated() {
        let mut e = Engine::new();
        e.spawn_at(0.0, "boom", |_ctx| {
            panic!("kaboom {}", 42);
        });
        match e.run() {
            Err(EngineError::ActivityPanic(_, label, msg)) => {
                assert_eq!(label, "boom");
                assert!(msg.contains("kaboom 42"));
            }
            other => panic!("expected panic error, got {other:?}"),
        }
    }

    #[test]
    fn spawn_from_inside_activity() {
        let mut e = Engine::new();
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        e.spawn_at(0.0, "parent", move |ctx| {
            ctx.advance(1.0);
            let c2 = c.clone();
            let child = ctx.spawn("child", move |cctx| {
                cctx.advance(3.0);
                c2.fetch_add(10, O::SeqCst);
            });
            assert_ne!(child, ctx.id());
            c.fetch_add(1, O::SeqCst);
        });
        let end = e.run().unwrap();
        assert_eq!(count.load(O::SeqCst), 11);
        assert!((end - 4.0).abs() < 1e-12, "end={end}");
    }

    #[test]
    fn equal_time_events_fifo() {
        // Two activities woken at the same instant run in insert order.
        let mut e = Engine::new();
        let log = Arc::new(Mutex::new(Vec::new()));
        for name in ["first", "second", "third"] {
            let l = log.clone();
            e.spawn_at(1.0, name, move |_ctx| {
                l.lock().unwrap().push(name);
            });
        }
        e.run().unwrap();
        assert_eq!(*log.lock().unwrap(), vec!["first", "second", "third"]);
    }

    #[test]
    fn determinism_two_runs_identical() {
        fn run_once() -> Vec<(usize, u64)> {
            let mut e = Engine::new();
            let log = Arc::new(Mutex::new(Vec::new()));
            for i in 0..8 {
                let l = log.clone();
                e.spawn_at(0.0, format!("w{i}"), move |ctx| {
                    let mut t = 0.001 * (i as f64 + 1.0);
                    for _ in 0..20 {
                        ctx.advance(t);
                        t *= 1.1;
                        l.lock().unwrap().push((i, (ctx.now() * 1e9) as u64));
                    }
                });
            }
            e.run().unwrap();
            let v = log.lock().unwrap().clone();
            v
        }
        assert_eq!(run_once(), run_once());
    }

    #[test]
    fn event_limit_guards_livelock() {
        let mut e = Engine::new();
        e.set_event_limit(100);
        e.spawn_at(0.0, "spinner", |ctx| loop {
            ctx.advance(0.0);
        });
        match e.run() {
            Err(EngineError::EventLimit(100)) => {}
            other => panic!("expected event limit, got {other:?}"),
        }
    }

    #[test]
    fn many_activities_scale() {
        let mut e = Engine::new();
        let n = 200;
        let done = Arc::new(AtomicUsize::new(0));
        for i in 0..n {
            let d = done.clone();
            e.spawn_at(0.0, format!("r{i}"), move |ctx| {
                for _ in 0..50 {
                    ctx.advance(1e-6);
                }
                d.fetch_add(1, O::SeqCst);
            });
        }
        e.run().unwrap();
        assert_eq!(done.load(O::SeqCst), n);
    }
}
