//! The discrete-event engine: virtual clock, calendar event queue, and
//! the thread handoff protocol that suspends/resumes simulated
//! activities.
//!
//! ## Handoff protocol
//!
//! Every thread-backed activity owns a [`Handoff`] slot (mutex +
//! condvar).  The engine resumes an activity by storing `ToActivity`
//! and waits for the slot to flip back to `ToEngine(request)`; the
//! activity does the mirror image.  This gives strict alternation — at
//! most one activity body executes at a time — which is what makes
//! simulation runs deterministic regardless of OS scheduling.
//!
//! ## Event queue
//!
//! Events live in a bucketed **calendar queue** ([`CalendarQueue`]) by
//! default: each event is hashed into a time bucket by
//! `floor(time / width)`, pops walk the cursor bucket-by-bucket, and
//! the bucket count / width self-tune to keep occupancy near one event
//! per bucket.  Pop order is the exact `(time, seq)` minimum, so the
//! calendar is **bit-identical** to the seed `BinaryHeap` — the old
//! heap is retained behind [`QueueKind::Heap`] and an equivalence
//! harness asserts identical outputs across both.
//!
//! ## Activity arena
//!
//! Activities are arena-allocated: [`ActivityId`] is a dense index into
//! a `Vec<ActivitySlot>` (ids are assigned sequentially at spawn), so
//! every engine-side lookup is a bounds-checked array index instead of
//! a `HashMap` probe.
//!
//! ## Batched wakeups
//!
//! A collective releasing N ranks costs **one** engine event plus an
//! O(N) release sweep ([`Request::UnparkBatch`]): the batch is sorted
//! once, its head is pushed as a single queue event, and each released
//! rank that blocks again hands control directly to the next batch
//! entry when that entry is already the global minimum (a "direct
//! sweep" — zero queue operations).  Per-entry seq numbers are assigned
//! exactly as N individual unparks would have been, so release order
//! is bit-identical.
//!
//! ## Wakeups
//!
//! `park`/`unpark` use counting semantics (a pending-wake queue per
//! activity), so an `unpark` that is issued *before* the target parks
//! is never lost.  Higher layers are written condition-variable style:
//! `while !condition { ctx.park(); }` — spurious wakeups are allowed
//! and harmless.
//!
//! ## Snapshot / rollback
//!
//! [`Engine::run_until_idle`] returns (instead of reporting deadlock)
//! when every live activity is parked, [`Engine::unpark`] re-releases
//! activities from the host side, and [`Engine::rollback_to`] rewinds
//! the virtual clock at quiescence.  Together these let the planner's
//! DES micro-probes replay many candidates against one saved world
//! instead of rebuilding threads + topology per candidate.
//!
//! ## Lite activities
//!
//! [`Engine::spawn_lite_at`] registers a *thread-less* activity: a
//! state-machine closure the engine drives inline, one step per event.
//! A lite activity costs ~200 bytes instead of an OS thread, which is
//! what makes 10⁶-rank simulations routine (`proteo engine-stress`).

use std::collections::{BinaryHeap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use super::activity::ActivityCtx;

/// Virtual time in seconds.
pub type Time = f64;

/// Identifier of a simulated activity (process or auxiliary thread).
/// Dense: ids index the engine's activity arena in spawn order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ActivityId(pub usize);

/// Errors surfaced by [`Engine::run`].
#[derive(Debug)]
pub enum EngineError {
    Deadlock { time: Time, parked: usize, detail: String },
    ActivityPanic(ActivityId, String, String),
    EventLimit(u64),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::Deadlock { time, parked, detail } => write!(
                f,
                "deadlock at t={time:.9}s: {parked} activities parked, no pending events: {detail}"
            ),
            EngineError::ActivityPanic(id, label, msg) => {
                write!(f, "activity {id:?} ({label}) panicked: {msg}")
            }
            EngineError::EventLimit(n) => write!(f, "event limit of {n} exceeded (livelock guard)"),
        }
    }
}

impl std::error::Error for EngineError {}

/// What an activity asks the engine to do when it yields.
pub(crate) enum Request {
    /// Resume me at absolute virtual time `t` (compute / sleep).
    AdvanceUntil(Time),
    /// Park until some other activity unparks me.
    Park,
    /// Schedule a wakeup for `target` at absolute time `at`, then
    /// continue running me immediately.
    Unpark { target: ActivityId, at: Time },
    /// Schedule wakeups for many targets in one engine round-trip
    /// (collective release), then continue running me immediately.
    /// Per-entry ordering is identical to issuing the unparks one by
    /// one, but the engine pays one event + an O(N) sweep instead of
    /// N queue operations.
    UnparkBatch(Vec<(ActivityId, Time)>),
    /// Spawn a new activity starting at `at` (the caller's local time,
    /// which may be ahead of the engine clock under a lease); reply
    /// with its id, continue me immediately.
    Spawn { label: String, body: BodyFn, at: Time },
    /// Activity body finished (normally or by panic) at local time `at`.
    Exit { panic_msg: Option<String>, at: Time },
}

pub(crate) type BodyFn = Box<dyn FnOnce(ActivityCtx) + Send + 'static>;

/// Value the engine passes back when it resumes an activity.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Resume {
    /// Current virtual time.
    pub now: Time,
    /// Reply value (spawn returns the new ActivityId here).
    pub reply: usize,
    /// §Perf-L3 time lease: the activity may advance its local clock up
    /// to (strictly below) this instant WITHOUT a handoff — no other
    /// event precedes it, and since exactly one activity runs at a
    /// time, none can appear.  The engine↔activity thread ping-pong
    /// (~5–10 µs of futex traffic per simulated call) is the DES's
    /// dominant cost; leases remove it for every compute segment that
    /// fits before the next scheduled event.
    pub lease: Time,
    /// Set on the first resume after [`Engine::rollback_to`]: the
    /// activity must adopt `now` even though it moves its local clock
    /// backwards.
    pub reset: bool,
}

pub(crate) enum Slot {
    Empty,
    ToActivity(Resume),
    ToEngine(Request),
}

/// One mutex+condvar pair per activity; both sides block on it.
pub(crate) struct Handoff {
    pub slot: Mutex<Slot>,
    pub cv: Condvar,
}

impl Handoff {
    fn new() -> Arc<Handoff> {
        Arc::new(Handoff { slot: Mutex::new(Slot::Empty), cv: Condvar::new() })
    }

    /// Engine side: hand control to the activity and wait for its next
    /// request.
    fn engine_step(&self, resume: Resume) -> Request {
        let mut slot = self.slot.lock().unwrap();
        *slot = Slot::ToActivity(resume);
        self.cv.notify_all();
        loop {
            match std::mem::replace(&mut *slot, Slot::Empty) {
                Slot::ToEngine(req) => return req,
                other => {
                    *slot = other;
                    slot = self.cv.wait(slot).unwrap();
                }
            }
        }
    }

    /// Activity side: submit a request and wait to be resumed.
    pub(crate) fn activity_yield(&self, req: Request) -> Resume {
        let mut slot = self.slot.lock().unwrap();
        *slot = Slot::ToEngine(req);
        self.cv.notify_all();
        loop {
            match std::mem::replace(&mut *slot, Slot::Empty) {
                Slot::ToActivity(r) => return r,
                other => {
                    *slot = other;
                    slot = self.cv.wait(slot).unwrap();
                }
            }
        }
    }

    /// Activity side: final request (Exit) — posts without waiting for
    /// a resume, so the worker thread can move on to its next job.
    fn activity_finish(&self, req: Request) {
        let mut slot = self.slot.lock().unwrap();
        *slot = Slot::ToEngine(req);
        self.cv.notify_all();
    }

    /// Activity side: first wait (job start) — no request submitted.
    fn activity_wait_first(&self) -> Resume {
        let mut slot = self.slot.lock().unwrap();
        loop {
            match std::mem::replace(&mut *slot, Slot::Empty) {
                Slot::ToActivity(r) => return r,
                other => {
                    *slot = other;
                    slot = self.cv.wait(slot).unwrap();
                }
            }
        }
    }
}

/// Which event-queue implementation an [`Engine`] uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueKind {
    /// The seed binary heap (kept for equivalence testing).
    Heap,
    /// The bucketed calendar queue (default).
    Calendar,
}

static DEFAULT_QUEUE_KIND: AtomicU8 = AtomicU8::new(1);

/// Set the process-wide default queue kind used by [`Engine::new`].
/// The equivalence harness flips this to run identical workloads on
/// both implementations.
pub fn set_default_queue_kind(kind: QueueKind) {
    DEFAULT_QUEUE_KIND.store(
        match kind {
            QueueKind::Heap => 0,
            QueueKind::Calendar => 1,
        },
        Ordering::SeqCst,
    );
}

/// The process-wide default queue kind.
pub fn default_queue_kind() -> QueueKind {
    if DEFAULT_QUEUE_KIND.load(Ordering::SeqCst) == 0 {
        QueueKind::Heap
    } else {
        QueueKind::Calendar
    }
}

/// What a queued event resumes.
#[derive(Clone, Copy, Debug)]
enum EvTarget {
    /// Resume one activity.
    Act(ActivityId),
    /// Resume the next pending entry of a wakeup batch (slab index).
    Batch(usize),
}

/// Queue event: resume `target` at `time`.  `seq` breaks ties FIFO so
/// equal-time events are processed in insertion order (determinism).
#[derive(Clone, Copy, Debug)]
struct Event {
    time: Time,
    seq: u64,
    target: EvTarget,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// `(time, seq)` strict ordering shared by both queue implementations.
#[inline]
fn key_lt(a: (Time, u64), b: (Time, u64)) -> bool {
    a.0 < b.0 || (a.0 == b.0 && a.1 < b.1)
}

const CAL_MIN_BUCKETS: usize = 64;

struct CalEntry {
    /// Precomputed absolute bucket index `floor(time / width)` —
    /// computed once per push so float boundary arithmetic can never
    /// disagree between push and pop.
    abs: u64,
    ev: Event,
}

/// Bucketed calendar queue with exact `(time, seq)` pop order.
///
/// The cursor `cur` is an *absolute* bucket index; the structural
/// invariant is that no live entry has `abs < cur` (pushes clamp the
/// cursor down, so it can never strand an entry behind itself).  Pops
/// walk the cursor forward at most one lap before falling back to a
/// global minimum scan (sparse far-future regions), and a memoized
/// minimum makes the peek-then-pop pattern cost one scan per event.
/// Width and bucket count self-tune from the live event spread.
struct CalendarQueue {
    buckets: Vec<Vec<CalEntry>>,
    /// Bucket width in virtual seconds.
    width: f64,
    /// Absolute bucket index of the cursor; no entry is below it.
    cur: u64,
    len: usize,
    /// Memoized minimum `(time, seq, bucket slot, position)`.  Valid
    /// until the next pop: pushes keep it fresh (appends never move
    /// entries), only `swap_remove` invalidates positions.
    memo: Option<(Time, u64, usize, usize)>,
    /// Entries + buckets examined by the last `ensure_memo` scan —
    /// feeds the occupancy self-tuning.
    scan_cost: usize,
    pops: u64,
    last_retune_pops: u64,
}

impl CalendarQueue {
    fn new() -> CalendarQueue {
        CalendarQueue {
            buckets: (0..CAL_MIN_BUCKETS).map(|_| Vec::new()).collect(),
            width: 1e-5,
            cur: 0,
            len: 0,
            memo: None,
            scan_cost: 0,
            pops: 0,
            last_retune_pops: 0,
        }
    }

    fn abs_bucket(&self, time: Time) -> u64 {
        if time <= 0.0 {
            return 0;
        }
        let b = time / self.width;
        if b >= u64::MAX as f64 {
            u64::MAX
        } else {
            b as u64
        }
    }

    fn push(&mut self, ev: Event) {
        let abs = self.abs_bucket(ev.time);
        if abs < self.cur {
            self.cur = abs;
        }
        let slot = (abs % self.buckets.len() as u64) as usize;
        let (t, s) = (ev.time, ev.seq);
        self.buckets[slot].push(CalEntry { abs, ev });
        let pos = self.buckets[slot].len() - 1;
        if let Some((mt, ms, _, _)) = self.memo {
            if key_lt((t, s), (mt, ms)) {
                self.memo = Some((t, s, slot, pos));
            }
        }
        // A memo of None stays None: the new entry may or may not be
        // the minimum, and peek recomputes lazily.
        self.len += 1;
        if self.len > 4 * self.buckets.len() {
            self.resize(self.buckets.len() * 2);
        }
    }

    /// Locate the global minimum, advancing the cursor past empty
    /// buckets, and memoize it.
    fn ensure_memo(&mut self) -> Option<(Time, u64, usize, usize)> {
        if self.len == 0 {
            return None;
        }
        if self.memo.is_some() {
            return self.memo;
        }
        let n = self.buckets.len() as u64;
        let mut cost = 0usize;
        for _ in 0..self.buckets.len() {
            let slot = (self.cur % n) as usize;
            let mut best: Option<(Time, u64, usize)> = None;
            cost += 1 + self.buckets[slot].len();
            for (pos, e) in self.buckets[slot].iter().enumerate() {
                if e.abs == self.cur {
                    let k = (e.ev.time, e.ev.seq);
                    if best.is_none() || key_lt(k, (best.unwrap().0, best.unwrap().1)) {
                        best = Some((k.0, k.1, pos));
                    }
                }
            }
            if let Some((t, s, pos)) = best {
                self.memo = Some((t, s, slot, pos));
                self.scan_cost = cost;
                return self.memo;
            }
            if self.cur == u64::MAX {
                break;
            }
            self.cur += 1;
        }
        // Sparse far-future region: one global scan for the minimum,
        // then jump the cursor to its bucket (a "calendar year" skip).
        let mut best: Option<(u64, Time, u64, usize, usize)> = None;
        for (slot, b) in self.buckets.iter().enumerate() {
            cost += b.len();
            for (pos, e) in b.iter().enumerate() {
                let k = (e.ev.time, e.ev.seq);
                let better = match best {
                    None => true,
                    Some((_, bt, bs, _, _)) => key_lt(k, (bt, bs)),
                };
                if better {
                    best = Some((e.abs, k.0, k.1, slot, pos));
                }
            }
        }
        let (abs, t, s, slot, pos) = best.expect("len > 0 but no entries found");
        self.cur = abs;
        self.memo = Some((t, s, slot, pos));
        self.scan_cost = cost;
        self.memo
    }

    fn peek_key(&mut self) -> Option<(Time, u64)> {
        self.ensure_memo().map(|(t, s, _, _)| (t, s))
    }

    fn pop(&mut self) -> Option<Event> {
        let (_, _, slot, pos) = self.ensure_memo()?;
        let e = self.buckets[slot].swap_remove(pos);
        self.len -= 1;
        self.memo = None;
        self.cur = e.abs;
        self.pops += 1;
        if self.buckets.len() > CAL_MIN_BUCKETS && self.len < self.buckets.len() / 4 {
            self.resize((self.buckets.len() / 2).max(CAL_MIN_BUCKETS));
        } else if self.scan_cost > 8
            && self.len > 32
            && self.pops >= self.last_retune_pops + self.len as u64
        {
            // Expensive scans mean the width no longer matches the
            // event spread (all clustered in one bucket, or spread so
            // thin every pop laps the calendar).  Rebuild with a width
            // re-derived from the live entries; amortized by requiring
            // `len` pops between retunes.
            self.last_retune_pops = self.pops;
            let n = self.len.next_power_of_two().max(CAL_MIN_BUCKETS);
            self.resize(n);
        }
        Some(e.ev)
    }

    fn resize(&mut self, n: usize) {
        let mut all: Vec<CalEntry> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            all.append(b);
        }
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for e in &all {
            if e.ev.time.is_finite() {
                lo = lo.min(e.ev.time);
                hi = hi.max(e.ev.time);
            }
        }
        if hi > lo && all.len() > 1 {
            let w = (hi - lo) / (all.len() as f64);
            if w.is_finite() && w > 0.0 {
                self.width = w;
            }
        }
        self.buckets = (0..n).map(|_| Vec::new()).collect();
        self.len = all.len();
        self.memo = None;
        self.cur = u64::MAX;
        for e in all {
            // Recompute abs under the (possibly) new width.
            let abs = self.abs_bucket(e.ev.time);
            if abs < self.cur {
                self.cur = abs;
            }
            let slot = (abs % n as u64) as usize;
            self.buckets[slot].push(CalEntry { abs, ev: e.ev });
        }
        if self.len == 0 {
            self.cur = 0;
        }
    }

    fn reset_cursor(&mut self, t: Time) {
        debug_assert!(self.len == 0);
        self.cur = self.abs_bucket(t);
        self.memo = None;
    }
}

/// The engine's event queue: the calendar queue by default, the seed
/// binary heap behind [`QueueKind::Heap`] for equivalence testing.
/// Both pop the exact `(time, seq)` minimum.
enum EventQueue {
    Heap(BinaryHeap<Event>),
    Calendar(CalendarQueue),
}

impl EventQueue {
    fn new(kind: QueueKind) -> EventQueue {
        match kind {
            QueueKind::Heap => EventQueue::Heap(BinaryHeap::new()),
            QueueKind::Calendar => EventQueue::Calendar(CalendarQueue::new()),
        }
    }

    fn push(&mut self, ev: Event) {
        match self {
            EventQueue::Heap(h) => h.push(ev),
            EventQueue::Calendar(c) => c.push(ev),
        }
    }

    fn pop(&mut self) -> Option<Event> {
        match self {
            EventQueue::Heap(h) => h.pop(),
            EventQueue::Calendar(c) => c.pop(),
        }
    }

    fn peek_key(&mut self) -> Option<(Time, u64)> {
        match self {
            EventQueue::Heap(h) => h.peek().map(|e| (e.time, e.seq)),
            EventQueue::Calendar(c) => c.peek_key(),
        }
    }

    fn len(&self) -> usize {
        match self {
            EventQueue::Heap(h) => h.len(),
            EventQueue::Calendar(c) => c.len,
        }
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn reset_cursor(&mut self, t: Time) {
        if let EventQueue::Calendar(c) = self {
            c.reset_cursor(t);
        }
    }
}

/// One step result of a lite activity's state machine.
pub enum LiteStep {
    /// Resume me at absolute virtual time `t`.
    AdvanceUntil(Time),
    /// Park until unparked.
    Park,
    /// Finished.
    Done,
}

enum LiteEffect {
    Unpark(ActivityId, Time),
    UnparkBatch(Vec<(ActivityId, Time)>),
}

/// Context handle a lite activity's step closure runs against.
/// Effects (unparks) are queued and applied in order by the engine
/// right after the step returns, before the step result is handled.
pub struct LiteCtx {
    now: Time,
    effects: Vec<LiteEffect>,
}

impl LiteCtx {
    /// Current virtual time.
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule a wakeup for `target` at absolute time `at`.
    pub fn unpark_at(&mut self, target: ActivityId, at: Time) {
        self.effects.push(LiteEffect::Unpark(target, at));
    }

    /// Schedule wakeups for many targets in one batch.
    pub fn unpark_batch(&mut self, entries: Vec<(ActivityId, Time)>) {
        if !entries.is_empty() {
            self.effects.push(LiteEffect::UnparkBatch(entries));
        }
    }
}

type LiteBody = Box<dyn FnMut(&mut LiteCtx) -> LiteStep + Send + 'static>;

/// A worker-pool job: one activity body plus its handoff + context.
struct Job {
    handoff: Arc<Handoff>,
    ctx: ActivityCtx,
    body: BodyFn,
}

/// Idle simulation worker threads, shared process-wide.  A fig sweep
/// runs tens of thousands of short-lived simulated processes; reusing
/// OS threads across them removes the dominant spawn/join cost.
static WORKER_POOL: OnceLock<Mutex<Vec<mpsc::Sender<Job>>>> = OnceLock::new();
const WORKER_POOL_CAP: usize = 1024;

fn worker_pool() -> &'static Mutex<Vec<mpsc::Sender<Job>>> {
    WORKER_POOL.get_or_init(|| Mutex::new(Vec::new()))
}

fn worker_loop(rx: mpsc::Receiver<Job>) {
    while let Ok(Job { handoff, ctx, body }) = rx.recv() {
        let first = handoff.activity_wait_first();
        ctx.set_now(first.now);
        ctx.set_lease(first.lease);
        let ctx2 = ctx.clone();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(ctx);
        }));
        let panic_msg = result.err().map(|e| {
            e.downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string())
        });
        // Final post: do not wait for a resume — the engine returns
        // this worker to the pool right after handling Exit.  Carry
        // the final local time so lease-advanced clocks are reflected
        // in the engine clock.
        handoff.activity_finish(Request::Exit { panic_msg, at: ctx2.now() });
    }
}

/// Hand `job` to an idle pooled worker, or spawn a fresh one.
fn dispatch_job(mut job: Job) -> mpsc::Sender<Job> {
    loop {
        let reused = worker_pool().lock().unwrap().pop();
        let tx = match reused {
            Some(tx) => tx,
            None => {
                let (tx, rx) = mpsc::channel::<Job>();
                std::thread::Builder::new()
                    .name("sim-worker".to_string())
                    .stack_size(1 << 20)
                    .spawn(move || worker_loop(rx))
                    .expect("spawn simulation worker thread");
                tx
            }
        };
        match tx.send(job) {
            Ok(()) => return tx,
            Err(mpsc::SendError(j)) => job = j, // worker gone; try another
        }
    }
}

fn return_worker(tx: mpsc::Sender<Job>) {
    let mut pool = worker_pool().lock().unwrap();
    if pool.len() < WORKER_POOL_CAP {
        pool.push(tx);
    }
}

enum SlotBody {
    /// Thread-backed activity (the default): handoff + the pooled
    /// worker currently running its body.
    Thread { handoff: Arc<Handoff>, worker: Option<mpsc::Sender<Job>> },
    /// Thread-less state-machine activity driven inline by the engine.
    /// `None` while the closure is checked out for a step (or done).
    Lite(Option<LiteBody>),
}

struct ActivitySlot {
    label: String,
    body: SlotBody,
    /// Wakeups delivered while the activity was not parked.
    pending_wakes: VecDeque<Time>,
    parked: bool,
    done: bool,
    /// Set by [`Engine::rollback_to`]; the next resume carries
    /// `reset = true` so the activity adopts the rewound clock.
    needs_reset: bool,
}

/// A pending collective release: entries sorted by `(time, seq)`,
/// `next` pointing at the first undelivered one.  Exactly one queue
/// event exists per batch (for `entries[next]`) unless the batch is
/// mid-sweep.
struct BatchRelease {
    entries: Vec<(Time, u64, ActivityId)>,
    next: usize,
}

/// Engine observability counters (see `util::benchkit` rows and the
/// scenario JSON `engine` object).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// Activity resumes processed (same metric the seed engine counted).
    pub events: u64,
    /// Peak event-queue depth.
    pub peak_queue: usize,
    /// Batched-wakeup requests handled.
    pub wakeup_batches: u64,
    /// Total wakeups delivered through batches.
    pub wakeup_batched: u64,
    /// Largest single wakeup batch.
    pub wakeup_max_batch: usize,
    /// Batch entries delivered by direct sweep (zero queue operations).
    pub direct_sweeps: u64,
    /// Host-side clock rollbacks (incremental probe reuse).
    pub rollbacks: u64,
    /// World snapshots taken against this engine (noted by the prober).
    pub snapshots: u64,
}

/// Shared counters the [`ActivityCtx`] can read without a handoff.
pub(crate) struct EngineShared {
    /// Monotone count of processed events — cheap progress metric.
    pub events_processed: AtomicU64,
}

/// The discrete-event engine.
pub struct Engine {
    queue: EventQueue,
    seq: u64,
    clock: Time,
    activities: Vec<ActivitySlot>,
    alive: usize,
    batches: Vec<Option<BatchRelease>>,
    batch_free: Vec<usize>,
    stats: EngineStats,
    pub(crate) shared: Arc<EngineShared>,
    /// Livelock guard; configurable via [`Engine::set_event_limit`].
    event_limit: u64,
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine {
    /// Engine with the process-wide default queue kind.
    pub fn new() -> Engine {
        Self::with_queue(default_queue_kind())
    }

    /// Engine with an explicit queue kind (equivalence testing).
    pub fn with_queue(kind: QueueKind) -> Engine {
        Engine {
            queue: EventQueue::new(kind),
            seq: 0,
            clock: 0.0,
            activities: Vec::new(),
            alive: 0,
            batches: Vec::new(),
            batch_free: Vec::new(),
            stats: EngineStats::default(),
            shared: Arc::new(EngineShared { events_processed: AtomicU64::new(0) }),
            event_limit: 500_000_000,
        }
    }

    /// Lower the livelock guard (useful in tests).
    pub fn set_event_limit(&mut self, limit: u64) {
        self.event_limit = limit;
    }

    /// Current virtual time (valid between `run` calls or after run).
    pub fn now(&self) -> Time {
        self.clock
    }

    /// Total events processed so far (simulator throughput metric).
    pub fn events_processed(&self) -> u64 {
        self.shared.events_processed.load(Ordering::Relaxed)
    }

    /// Observability counters (events, queue depth, batching, rollback).
    pub fn stats(&self) -> EngineStats {
        let mut s = self.stats;
        s.events = self.events_processed();
        s
    }

    /// Mutable counters — the prober notes world snapshots here.
    pub fn stats_mut(&mut self) -> &mut EngineStats {
        &mut self.stats
    }

    fn push_ev(&mut self, ev: Event) {
        self.queue.push(ev);
        let d = self.queue.len();
        if d > self.stats.peak_queue {
            self.stats.peak_queue = d;
        }
    }

    fn push_event(&mut self, time: Time, activity: ActivityId) {
        self.seq += 1;
        let seq = self.seq;
        self.push_ev(Event { time, seq, target: EvTarget::Act(activity) });
    }

    /// Register an activity to start at virtual time `start`.
    pub fn spawn_at<F>(&mut self, start: Time, label: impl Into<String>, body: F) -> ActivityId
    where
        F: FnOnce(ActivityCtx) + Send + 'static,
    {
        let id = self.spawn_suspended(label, Box::new(body));
        self.push_event(start, id);
        id
    }

    /// Hand the activity body to a pooled worker without scheduling it.
    fn spawn_suspended(&mut self, label: impl Into<String>, body: BodyFn) -> ActivityId {
        let id = ActivityId(self.activities.len());
        let handoff = Handoff::new();
        let ctx = ActivityCtx::new(id, handoff.clone());
        let worker = dispatch_job(Job { handoff: handoff.clone(), ctx, body });
        self.activities.push(ActivitySlot {
            label: label.into(),
            body: SlotBody::Thread { handoff, worker: Some(worker) },
            pending_wakes: VecDeque::new(),
            parked: false,
            done: false,
            needs_reset: false,
        });
        self.alive += 1;
        id
    }

    /// Register a thread-less state-machine activity starting at
    /// `start`.  The engine calls `body` once per resume; the returned
    /// [`LiteStep`] decides what happens next.  Costs ~200 bytes
    /// instead of an OS thread — million-activity simulations are
    /// routine (`proteo engine-stress`).
    pub fn spawn_lite_at<F>(&mut self, start: Time, label: impl Into<String>, body: F) -> ActivityId
    where
        F: FnMut(&mut LiteCtx) -> LiteStep + Send + 'static,
    {
        let id = ActivityId(self.activities.len());
        self.activities.push(ActivitySlot {
            label: label.into(),
            body: SlotBody::Lite(Some(Box::new(body))),
            pending_wakes: VecDeque::new(),
            parked: false,
            done: false,
            needs_reset: false,
        });
        self.alive += 1;
        self.push_event(start, id);
        id
    }

    /// Host-side wakeup (engine not running): used by the planner's
    /// probe sessions to re-release ranks after [`Engine::rollback_to`].
    pub fn unpark(&mut self, target: ActivityId, at: Time) {
        self.handle_unpark(target, at);
    }

    /// Rewind the virtual clock to `t`.  Requires quiescence: an empty
    /// event queue and every live activity parked (the state
    /// [`Engine::run_until_idle`] returns in).  The next resume of each
    /// live activity carries `reset` so its local clock adopts `t`.
    pub fn rollback_to(&mut self, t: Time) {
        assert!(self.queue.is_empty(), "rollback_to requires an empty event queue");
        for st in self.activities.iter_mut() {
            if !st.done {
                assert!(st.parked, "rollback_to requires all live activities parked");
                st.pending_wakes.clear();
                st.needs_reset = true;
            }
        }
        self.clock = t;
        self.queue.reset_cursor(t);
        self.stats.rollbacks += 1;
    }

    /// Drive the simulation until every activity has finished.
    pub fn run(&mut self) -> Result<Time, EngineError> {
        let result = self.run_inner(false);
        if result.is_err() {
            self.abandon();
        }
        result
    }

    /// Drive the simulation until every activity has finished **or**
    /// every live activity is parked with no pending events (returns
    /// `Ok(clock)` at that quiescent point instead of reporting
    /// deadlock).  The probe-session building block: park ranks, read
    /// metrics, [`Engine::rollback_to`], [`Engine::unpark`], repeat.
    pub fn run_until_idle(&mut self) -> Result<Time, EngineError> {
        let result = self.run_inner(true);
        if result.is_err() {
            self.abandon();
        }
        result
    }

    /// On error, detach remaining activities so we don't hang on drop:
    /// they are parked forever; marking done lets everything unwind.
    /// Stuck workers (blocked in their handoff) are leaked, exactly as
    /// the seed engine leaked detached threads; they hold no engine
    /// locks, so this is safe.
    fn abandon(&mut self) {
        for st in self.activities.iter_mut() {
            st.done = true;
        }
        self.alive = 0;
    }

    fn alloc_batch(&mut self, b: BatchRelease) -> usize {
        if let Some(i) = self.batch_free.pop() {
            self.batches[i] = Some(b);
            i
        } else {
            self.batches.push(Some(b));
            self.batches.len() - 1
        }
    }

    fn free_batch(&mut self, i: usize) {
        self.batches[i] = None;
        self.batch_free.push(i);
    }

    fn handle_unpark(&mut self, target: ActivityId, at: Time) {
        let at = at.max(self.clock);
        if let Some(st) = self.activities.get_mut(target.0) {
            if st.done {
                // waking a finished activity is a no-op
            } else if st.parked {
                st.parked = false;
                self.push_event(at, target);
            } else {
                st.pending_wakes.push_back(at);
            }
        }
    }

    fn handle_unpark_batch(&mut self, entries: Vec<(ActivityId, Time)>) {
        self.stats.wakeup_batches += 1;
        if entries.len() > self.stats.wakeup_max_batch {
            self.stats.wakeup_max_batch = entries.len();
        }
        let mut rel: Vec<(Time, u64, ActivityId)> = Vec::new();
        for (target, at) in entries {
            let at = at.max(self.clock);
            let Some(st) = self.activities.get_mut(target.0) else { continue };
            if st.done {
                continue;
            }
            if st.parked {
                st.parked = false;
                self.seq += 1;
                rel.push((at, self.seq, target));
            } else {
                st.pending_wakes.push_back(at);
            }
        }
        self.stats.wakeup_batched += rel.len() as u64;
        if rel.is_empty() {
            return;
        }
        // Stable sort by time keeps ascending seqs within equal times,
        // so entry order is the exact (time, seq) order N individual
        // unpark events would have popped in.
        rel.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let (t0, s0) = (rel[0].0, rel[0].1);
        let bi = self.alloc_batch(BatchRelease { entries: rel, next: 0 });
        self.push_ev(Event { time: t0, seq: s0, target: EvTarget::Batch(bi) });
    }

    /// Next-event lease for an activity being resumed: the queue
    /// minimum, tightened by the current batch's next pending entry
    /// (which is intentionally *not* in the queue mid-sweep).
    fn lease_for(&mut self, cur_batch: Option<usize>) -> Time {
        let mut lease = self.queue.peek_key().map_or(f64::INFINITY, |(t, _)| t);
        if let Some(bi) = cur_batch {
            if let Some(b) = &self.batches[bi] {
                if b.next < b.entries.len() {
                    lease = lease.min(b.entries[b.next].0);
                }
            }
        }
        lease
    }

    /// Run `current` until it blocks (advance/park/exit).  Immediate
    /// requests (Unpark/UnparkBatch/Spawn) keep control in the same
    /// activity without a queue round-trip.
    fn resume_thread(
        &mut self,
        current: ActivityId,
        cur_batch: Option<usize>,
    ) -> Result<(), EngineError> {
        let mut reply: usize = 0;
        loop {
            let lease = self.lease_for(cur_batch);
            let now = self.clock;
            // §Perf: the handoff is borrowed for the step instead of
            // Arc-cloned per resume — the engine thread blocks inside
            // `engine_step`, nothing touches the activity arena
            // meanwhile, and the request is handled after the borrow
            // ends.
            let req = {
                let st = &mut self.activities[current.0];
                if st.done {
                    return Ok(()); // stale event for a finished activity
                }
                st.parked = false;
                let reset = std::mem::take(&mut st.needs_reset);
                let SlotBody::Thread { handoff, .. } = &st.body else {
                    unreachable!("thread resume on lite activity");
                };
                handoff.engine_step(Resume { now, reply, lease, reset })
            };
            self.shared.events_processed.fetch_add(1, Ordering::Relaxed);
            reply = 0;
            match req {
                Request::AdvanceUntil(t) => {
                    let t = t.max(self.clock);
                    self.push_event(t, current);
                    return Ok(());
                }
                Request::Park => {
                    let st = &mut self.activities[current.0];
                    if let Some(at) = st.pending_wakes.pop_front() {
                        // A wake was already queued: resume at its
                        // delivery time (>= now by construction).
                        let t = at.max(self.clock);
                        self.push_event(t, current);
                    } else {
                        st.parked = true;
                    }
                    return Ok(());
                }
                Request::Unpark { target, at } => {
                    self.handle_unpark(target, at);
                    // fall through: continue the same activity now
                }
                Request::UnparkBatch(entries) => {
                    self.handle_unpark_batch(entries);
                    // fall through: continue the same activity now
                }
                Request::Spawn { label, body, at } => {
                    let new_id = self.spawn_suspended(label, body);
                    self.push_event(at.max(self.clock), new_id);
                    reply = new_id.0;
                    // continue the same activity, replying the id
                }
                Request::Exit { panic_msg, at } => {
                    self.clock = self.clock.max(at);
                    let st = &mut self.activities[current.0];
                    st.done = true;
                    st.parked = false;
                    // The activity is done: move the label out instead
                    // of cloning (it is only needed for the panic
                    // report; done activities never appear in deadlock
                    // details).
                    let label = std::mem::take(&mut st.label);
                    if let SlotBody::Thread { worker, .. } = &mut st.body {
                        if let Some(tx) = worker.take() {
                            return_worker(tx);
                        }
                    }
                    self.alive -= 1;
                    if let Some(msg) = panic_msg {
                        return Err(EngineError::ActivityPanic(current, label, msg));
                    }
                    return Ok(());
                }
            }
        }
    }

    /// Drive one step of a lite activity's state machine.
    fn resume_lite(&mut self, current: ActivityId) -> Result<(), EngineError> {
        let mut body = {
            let st = &mut self.activities[current.0];
            if st.done {
                return Ok(()); // stale event
            }
            st.parked = false;
            st.needs_reset = false; // lites read time from LiteCtx each step
            let SlotBody::Lite(b) = &mut st.body else {
                unreachable!("lite resume on thread activity");
            };
            b.take().expect("lite body re-entered")
        };
        let mut lctx = LiteCtx { now: self.clock, effects: Vec::new() };
        let step = body(&mut lctx);
        self.shared.events_processed.fetch_add(1, Ordering::Relaxed);
        {
            let st = &mut self.activities[current.0];
            let SlotBody::Lite(b) = &mut st.body else { unreachable!() };
            *b = Some(body);
        }
        for eff in lctx.effects {
            match eff {
                LiteEffect::Unpark(target, at) => self.handle_unpark(target, at),
                LiteEffect::UnparkBatch(entries) => self.handle_unpark_batch(entries),
            }
        }
        match step {
            LiteStep::AdvanceUntil(t) => {
                let t = t.max(self.clock);
                self.push_event(t, current);
            }
            LiteStep::Park => {
                let st = &mut self.activities[current.0];
                if let Some(at) = st.pending_wakes.pop_front() {
                    let t = at.max(self.clock);
                    self.push_event(t, current);
                } else {
                    st.parked = true;
                }
            }
            LiteStep::Done => {
                let st = &mut self.activities[current.0];
                st.done = true;
                st.parked = false;
                st.body = SlotBody::Lite(None);
                self.alive -= 1;
            }
        }
        Ok(())
    }

    fn resume_activity(
        &mut self,
        current: ActivityId,
        cur_batch: Option<usize>,
    ) -> Result<(), EngineError> {
        let is_lite = matches!(self.activities[current.0].body, SlotBody::Lite(_));
        if is_lite {
            self.resume_lite(current)
        } else {
            self.resume_thread(current, cur_batch)
        }
    }

    fn run_inner(&mut self, stop_at_idle: bool) -> Result<Time, EngineError> {
        let mut processed: u64 = 0;
        while self.alive > 0 {
            let Some(ev) = self.queue.pop() else {
                if stop_at_idle {
                    return Ok(self.clock);
                }
                let mut parked = 0usize;
                let mut detail = String::new();
                for st in self.activities.iter() {
                    if st.parked && !st.done {
                        parked += 1;
                        if !detail.is_empty() {
                            detail.push_str(", ");
                        }
                        detail.push_str(&st.label);
                    }
                }
                return Err(EngineError::Deadlock { time: self.clock, parked, detail });
            };
            debug_assert!(ev.time >= self.clock - 1e-12, "time went backwards");
            self.clock = self.clock.max(ev.time);
            let (mut current, mut cur_batch) = match ev.target {
                EvTarget::Act(a) => (a, None),
                EvTarget::Batch(bi) => {
                    let b = self.batches[bi].as_mut().expect("stale batch event");
                    let (_, _, a) = b.entries[b.next];
                    b.next += 1;
                    if b.next >= b.entries.len() {
                        self.free_batch(bi);
                        (a, None)
                    } else {
                        (a, Some(bi))
                    }
                }
            };
            // Drive until control returns to the queue: the current
            // activity runs until it blocks; if it came from a wakeup
            // batch whose next entry is already the global minimum,
            // sweep directly to that entry (zero queue operations).
            loop {
                processed += 1;
                if processed > self.event_limit {
                    return Err(EngineError::EventLimit(self.event_limit));
                }
                self.resume_activity(current, cur_batch)?;
                let Some(bi) = cur_batch else { break };
                let b = self.batches[bi].as_ref().expect("live batch");
                let (t2, s2, a2) = b.entries[b.next];
                let due_now = match self.queue.peek_key() {
                    None => true,
                    Some(k) => key_lt((t2, s2), k),
                };
                if due_now {
                    self.stats.direct_sweeps += 1;
                    let b = self.batches[bi].as_mut().unwrap();
                    b.next += 1;
                    if b.next >= b.entries.len() {
                        self.free_batch(bi);
                        cur_batch = None;
                    }
                    self.clock = self.clock.max(t2);
                    current = a2;
                } else {
                    self.push_ev(Event { time: t2, seq: s2, target: EvTarget::Batch(bi) });
                    break;
                }
            }
        }
        Ok(self.clock)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering as O};

    #[test]
    fn single_activity_advances_clock() {
        let mut e = Engine::new();
        let log = Arc::new(Mutex::new(Vec::new()));
        let l2 = log.clone();
        e.spawn_at(0.0, "a", move |ctx| {
            ctx.advance(1.5);
            l2.lock().unwrap().push(ctx.now());
            ctx.advance(0.5);
            l2.lock().unwrap().push(ctx.now());
        });
        let end = e.run().unwrap();
        assert!((end - 2.0).abs() < 1e-12);
        assert_eq!(*log.lock().unwrap(), vec![1.5, 2.0]);
    }

    #[test]
    fn two_activities_interleave_by_time() {
        let mut e = Engine::new();
        let log: Arc<Mutex<Vec<(&str, Time)>>> = Arc::new(Mutex::new(Vec::new()));
        let (la, lb) = (log.clone(), log.clone());
        e.spawn_at(0.0, "a", move |ctx| {
            ctx.advance(1.0);
            la.lock().unwrap().push(("a", ctx.now()));
            ctx.advance(2.0);
            la.lock().unwrap().push(("a", ctx.now()));
        });
        e.spawn_at(0.0, "b", move |ctx| {
            ctx.advance(2.0);
            lb.lock().unwrap().push(("b", ctx.now()));
        });
        e.run().unwrap();
        assert_eq!(
            *log.lock().unwrap(),
            vec![("a", 1.0), ("b", 2.0), ("a", 3.0)]
        );
    }

    #[test]
    fn park_unpark_roundtrip() {
        let mut e = Engine::new();
        let flag = Arc::new(AtomicUsize::new(0));
        let f2 = flag.clone();
        let waiter = e.spawn_at(0.0, "waiter", move |ctx| {
            ctx.park();
            f2.store(1, O::SeqCst);
            assert!((ctx.now() - 5.0).abs() < 1e-12);
        });
        e.spawn_at(0.0, "waker", move |ctx| {
            ctx.advance(2.0);
            ctx.unpark_at(waiter, 5.0);
        });
        e.run().unwrap();
        assert_eq!(flag.load(O::SeqCst), 1);
    }

    #[test]
    fn unpark_before_park_is_not_lost() {
        let mut e = Engine::new();
        let waiter = e.spawn_at(0.0, "late-parker", move |ctx| {
            // Do a long compute first; the wake arrives "during" it.
            ctx.advance(10.0);
            ctx.park(); // must complete because wake was queued
            assert!(ctx.now() >= 10.0);
        });
        e.spawn_at(0.0, "early-waker", move |ctx| {
            ctx.unpark_at(waiter, 1.0);
        });
        e.run().unwrap();
    }

    #[test]
    fn deadlock_is_detected() {
        let mut e = Engine::new();
        e.spawn_at(0.0, "stuck", |ctx| {
            ctx.park();
        });
        match e.run() {
            Err(EngineError::Deadlock { parked, detail, .. }) => {
                assert_eq!(parked, 1);
                assert!(detail.contains("stuck"));
            }
            other => panic!("expected deadlock, got {other:?}"),
        }
    }

    #[test]
    fn activity_panic_is_propagated() {
        let mut e = Engine::new();
        e.spawn_at(0.0, "boom", |_ctx| {
            panic!("kaboom {}", 42);
        });
        match e.run() {
            Err(EngineError::ActivityPanic(_, label, msg)) => {
                assert_eq!(label, "boom");
                assert!(msg.contains("kaboom 42"));
            }
            other => panic!("expected panic error, got {other:?}"),
        }
    }

    #[test]
    fn spawn_from_inside_activity() {
        let mut e = Engine::new();
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        e.spawn_at(0.0, "parent", move |ctx| {
            ctx.advance(1.0);
            let c2 = c.clone();
            let child = ctx.spawn("child", move |cctx| {
                cctx.advance(3.0);
                c2.fetch_add(10, O::SeqCst);
            });
            assert_ne!(child, ctx.id());
            c.fetch_add(1, O::SeqCst);
        });
        let end = e.run().unwrap();
        assert_eq!(count.load(O::SeqCst), 11);
        assert!((end - 4.0).abs() < 1e-12, "end={end}");
    }

    #[test]
    fn equal_time_events_fifo() {
        // Two activities woken at the same instant run in insert order.
        let mut e = Engine::new();
        let log = Arc::new(Mutex::new(Vec::new()));
        for name in ["first", "second", "third"] {
            let l = log.clone();
            e.spawn_at(1.0, name, move |_ctx| {
                l.lock().unwrap().push(name);
            });
        }
        e.run().unwrap();
        assert_eq!(*log.lock().unwrap(), vec!["first", "second", "third"]);
    }

    #[test]
    fn determinism_two_runs_identical() {
        fn run_once() -> Vec<(usize, u64)> {
            let mut e = Engine::new();
            let log = Arc::new(Mutex::new(Vec::new()));
            for i in 0..8 {
                let l = log.clone();
                e.spawn_at(0.0, format!("w{i}"), move |ctx| {
                    let mut t = 0.001 * (i as f64 + 1.0);
                    for _ in 0..20 {
                        ctx.advance(t);
                        t *= 1.1;
                        l.lock().unwrap().push((i, (ctx.now() * 1e9) as u64));
                    }
                });
            }
            e.run().unwrap();
            let v = log.lock().unwrap().clone();
            v
        }
        assert_eq!(run_once(), run_once());
    }

    #[test]
    fn event_limit_guards_livelock() {
        let mut e = Engine::new();
        e.set_event_limit(100);
        e.spawn_at(0.0, "spinner", |ctx| loop {
            ctx.advance(0.0);
        });
        match e.run() {
            Err(EngineError::EventLimit(100)) => {}
            other => panic!("expected event limit, got {other:?}"),
        }
    }

    #[test]
    fn many_activities_scale() {
        let mut e = Engine::new();
        let n = 200;
        let done = Arc::new(AtomicUsize::new(0));
        for i in 0..n {
            let d = done.clone();
            e.spawn_at(0.0, format!("r{i}"), move |ctx| {
                for _ in 0..50 {
                    ctx.advance(1e-6);
                }
                d.fetch_add(1, O::SeqCst);
            });
        }
        e.run().unwrap();
        assert_eq!(done.load(O::SeqCst), n);
    }

    /// The two queue kinds must order every workload identically.
    #[test]
    fn heap_and_calendar_order_identically() {
        fn run_once(kind: QueueKind) -> Vec<(usize, u64)> {
            let mut e = Engine::with_queue(kind);
            let log = Arc::new(Mutex::new(Vec::new()));
            for i in 0..16 {
                let l = log.clone();
                e.spawn_at(0.0, format!("w{i}"), move |ctx| {
                    // Mix of scales so entries cross calendar buckets,
                    // plus exact equal-time ties via zero advances.
                    let mut t = if i % 4 == 0 { 0.5 } else { 1e-6 * (i as f64 + 1.0) };
                    for k in 0..40 {
                        ctx.advance(t);
                        if k % 7 == 0 {
                            ctx.advance(0.0); // explicit yield point
                        }
                        t *= if i % 3 == 0 { 3.0 } else { 1.05 };
                        l.lock().unwrap().push((i, ctx.now().to_bits()));
                    }
                });
            }
            e.run().unwrap();
            let v = log.lock().unwrap().clone();
            v
        }
        assert_eq!(run_once(QueueKind::Heap), run_once(QueueKind::Calendar));
    }

    /// A batched release resumes each rank at exactly the time an
    /// individual unpark would have.
    #[test]
    fn unpark_batch_matches_individual_unparks() {
        fn run_once(batched: bool) -> Vec<(usize, u64)> {
            let mut e = Engine::new();
            let log = Arc::new(Mutex::new(Vec::new()));
            let mut ids = Vec::new();
            for i in 0..12 {
                let l = log.clone();
                ids.push(e.spawn_at(0.0, format!("r{i}"), move |ctx| {
                    ctx.park();
                    l.lock().unwrap().push((i, ctx.now().to_bits()));
                    ctx.advance(1e-6 * (i as f64 + 1.0));
                    l.lock().unwrap().push((i, ctx.now().to_bits()));
                }));
            }
            e.spawn_at(0.0, "releaser", move |ctx| {
                ctx.advance(1.0);
                // Release times deliberately unsorted with ties.
                let entries: Vec<_> = ids
                    .iter()
                    .enumerate()
                    .map(|(i, id)| (*id, 1.0 + 1e-7 * ((i * 5) % 3) as f64))
                    .collect();
                if batched {
                    ctx.unpark_batch(entries);
                } else {
                    for (id, t) in entries {
                        ctx.unpark_at(id, t);
                    }
                }
            });
            e.run().unwrap();
            let v = log.lock().unwrap().clone();
            v
        }
        assert_eq!(run_once(true), run_once(false));
    }

    /// Lite activities interleave with thread activities by time.
    #[test]
    fn lite_activities_run_and_interleave() {
        let mut e = Engine::new();
        let log = Arc::new(Mutex::new(Vec::new()));
        let l1 = log.clone();
        let mut step = 0usize;
        e.spawn_lite_at(0.0, "lite", move |lc| {
            step += 1;
            l1.lock().unwrap().push(("lite", lc.now()));
            match step {
                1 => LiteStep::AdvanceUntil(1.5),
                2 => LiteStep::AdvanceUntil(2.5),
                _ => LiteStep::Done,
            }
        });
        let l2 = log.clone();
        e.spawn_at(0.0, "thread", move |ctx| {
            ctx.advance(2.0);
            l2.lock().unwrap().push(("thread", ctx.now()));
        });
        let end = e.run().unwrap();
        assert!((end - 2.5).abs() < 1e-12);
        assert_eq!(
            *log.lock().unwrap(),
            vec![("lite", 0.0), ("lite", 1.5), ("thread", 2.0), ("lite", 2.5)]
        );
    }

    /// Lite park/unpark, including a lite-to-lite batch release.
    #[test]
    fn lite_park_and_batch_release() {
        let mut e = Engine::new();
        let released = Arc::new(Mutex::new(Vec::new()));
        let mut members = Vec::new();
        for i in 0..5 {
            let r = released.clone();
            let mut first = true;
            members.push(e.spawn_lite_at(0.0, format!("m{i}"), move |lc| {
                if first {
                    first = false;
                    return LiteStep::Park;
                }
                r.lock().unwrap().push((i, lc.now()));
                LiteStep::Done
            }));
        }
        let mut fired = false;
        e.spawn_lite_at(0.0, "coord", move |lc| {
            if !fired {
                fired = true;
                let entries: Vec<_> = members.iter().map(|m| (*m, 2.0)).collect();
                lc.unpark_batch(entries);
                return LiteStep::AdvanceUntil(3.0);
            }
            LiteStep::Done
        });
        e.run().unwrap();
        assert_eq!(
            *released.lock().unwrap(),
            vec![(0, 2.0), (1, 2.0), (2, 2.0), (3, 2.0), (4, 2.0)]
        );
    }

    /// run_until_idle + rollback_to + unpark replay a parked world.
    #[test]
    fn idle_rollback_unpark_replays() {
        let mut e = Engine::new();
        let log = Arc::new(Mutex::new(Vec::new()));
        let l = log.clone();
        let worker = e.spawn_at(0.0, "w", move |ctx| loop {
            ctx.park();
            if ctx.now() > 90.0 {
                break; // shutdown signal: a wake far in the future
            }
            ctx.advance(0.25);
            l.lock().unwrap().push(ctx.now().to_bits());
        });
        let t = e.run_until_idle().unwrap();
        assert_eq!(t, 0.0);
        for _ in 0..3 {
            e.unpark(worker, 1.0);
            let t = e.run_until_idle().unwrap();
            assert!((t - 1.25).abs() < 1e-12);
            e.rollback_to(0.0);
            assert_eq!(e.now(), 0.0);
        }
        // Identical wake → identical trajectory after every rollback.
        let bits = log.lock().unwrap().clone();
        assert_eq!(bits.len(), 3);
        assert!(bits.windows(2).all(|w| w[0] == w[1]));
        // Shutdown.
        e.unpark(worker, 100.0);
        e.run().unwrap();
        assert_eq!(e.stats().rollbacks, 3);
    }

    /// Stats counters move and the batch machinery reports itself.
    #[test]
    fn stats_counters_track_batches() {
        let mut e = Engine::new();
        let mut ids = Vec::new();
        for i in 0..8 {
            ids.push(e.spawn_at(0.0, format!("r{i}"), move |ctx| {
                ctx.park();
                ctx.advance(1e-6);
            }));
        }
        e.spawn_at(0.0, "rel", move |ctx| {
            ctx.advance(1.0);
            ctx.unpark_batch(ids.iter().map(|id| (*id, 1.0)).collect());
        });
        e.run().unwrap();
        let s = e.stats();
        assert_eq!(s.wakeup_batches, 1);
        assert_eq!(s.wakeup_batched, 8);
        assert_eq!(s.wakeup_max_batch, 8);
        assert!(s.events > 0);
        assert!(s.peak_queue >= 2);
    }

    /// Calendar queue survives adversarial spreads: huge jumps, dense
    /// clusters, and the resizes they trigger.
    #[test]
    fn calendar_queue_handles_sparse_and_dense_mixes() {
        fn run_once(kind: QueueKind) -> Vec<u64> {
            let mut e = Engine::with_queue(kind);
            let log = Arc::new(Mutex::new(Vec::new()));
            for i in 0..6 {
                let l = log.clone();
                e.spawn_at(0.0, format!("j{i}"), move |ctx| {
                    // Dense microsecond phase …
                    for _ in 0..30 {
                        ctx.advance(1e-6);
                        l.lock().unwrap().push(ctx.now().to_bits());
                    }
                    // … then a huge jump (bucket-lap + global scan), …
                    ctx.advance(1e4 * (i as f64 + 1.0));
                    l.lock().unwrap().push(ctx.now().to_bits());
                    // … then dense again.
                    for _ in 0..30 {
                        ctx.advance(1e-3);
                        l.lock().unwrap().push(ctx.now().to_bits());
                    }
                });
            }
            e.run().unwrap();
            let v = log.lock().unwrap().clone();
            v
        }
        assert_eq!(run_once(QueueKind::Heap), run_once(QueueKind::Calendar));
    }
}
