//! Real linear-algebra substrate: CSR matrices, test-problem
//! generators and a Conjugate Gradient solver ([25] in the paper).
//!
//! This backs the end-to-end examples: a *real* CG solve runs through
//! the malleability machinery (blocks of the CSR arrays and the
//! iterate are what MaM redistributes), and its per-iteration compute
//! can be executed either by [`spmv`]/[`cg`] here or by the
//! AOT-compiled JAX/Pallas step through [`runtime`](crate::runtime).
//! Both paths must produce the same residual history — that is the
//! cross-layer validation.

pub mod ell;

pub use ell::EllMatrix;

/// Compressed-sparse-row matrix (square).
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub n: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<usize>,
    pub vals: Vec<f64>,
}

impl Csr {
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Validate structural invariants; returns an error description.
    pub fn validate(&self) -> Result<(), String> {
        if self.row_ptr.len() != self.n + 1 {
            return Err(format!("row_ptr len {} != n+1", self.row_ptr.len()));
        }
        if self.row_ptr[0] != 0 || *self.row_ptr.last().unwrap() != self.nnz() {
            return Err("row_ptr endpoints wrong".into());
        }
        if self.col_idx.len() != self.vals.len() {
            return Err("col_idx/vals length mismatch".into());
        }
        for w in self.row_ptr.windows(2) {
            if w[1] < w[0] {
                return Err("row_ptr not monotone".into());
            }
        }
        if self.col_idx.iter().any(|&c| c >= self.n) {
            return Err("column index out of range".into());
        }
        Ok(())
    }

    /// Rows `[r0, r1)` as a standalone shard (local row_ptr rebased).
    pub fn row_slice(&self, r0: usize, r1: usize) -> CsrShard {
        assert!(r0 <= r1 && r1 <= self.n);
        let lo = self.row_ptr[r0];
        let hi = self.row_ptr[r1];
        CsrShard {
            n_global: self.n,
            row0: r0,
            row_ptr: self.row_ptr[r0..=r1].iter().map(|p| p - lo).collect(),
            col_idx: self.col_idx[lo..hi].to_vec(),
            vals: self.vals[lo..hi].to_vec(),
        }
    }
}

/// A contiguous row shard of a global CSR matrix (what one rank owns
/// under the block distribution).
#[derive(Clone, Debug, PartialEq)]
pub struct CsrShard {
    pub n_global: usize,
    pub row0: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<usize>,
    pub vals: Vec<f64>,
}

impl CsrShard {
    pub fn rows(&self) -> usize {
        self.row_ptr.len() - 1
    }

    /// y = A_shard · x (x is the full global vector).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n_global);
        assert_eq!(y.len(), self.rows());
        for r in 0..self.rows() {
            let mut acc = 0.0;
            for k in self.row_ptr[r]..self.row_ptr[r + 1] {
                acc += self.vals[k] * x[self.col_idx[k]];
            }
            y[r] = acc;
        }
    }
}

/// 1-D Laplacian (tridiagonal [-1, 2, -1]) — SPD, CG-friendly.
pub fn laplacian_1d(n: usize) -> Csr {
    let mut row_ptr = Vec::with_capacity(n + 1);
    let mut col_idx = Vec::new();
    let mut vals = Vec::new();
    row_ptr.push(0);
    for i in 0..n {
        if i > 0 {
            col_idx.push(i - 1);
            vals.push(-1.0);
        }
        col_idx.push(i);
        vals.push(2.0);
        if i + 1 < n {
            col_idx.push(i + 1);
            vals.push(-1.0);
        }
        row_ptr.push(col_idx.len());
    }
    Csr { n, row_ptr, col_idx, vals }
}

/// 2-D 5-point Laplacian on a `k × k` grid (n = k²) — the classic CG
/// benchmark problem.
pub fn laplacian_2d(k: usize) -> Csr {
    let n = k * k;
    let mut row_ptr = Vec::with_capacity(n + 1);
    let mut col_idx = Vec::new();
    let mut vals = Vec::new();
    row_ptr.push(0);
    for i in 0..k {
        for j in 0..k {
            let r = i * k + j;
            if i > 0 {
                col_idx.push(r - k);
                vals.push(-1.0);
            }
            if j > 0 {
                col_idx.push(r - 1);
                vals.push(-1.0);
            }
            col_idx.push(r);
            vals.push(4.0);
            if j + 1 < k {
                col_idx.push(r + 1);
                vals.push(-1.0);
            }
            if i + 1 < k {
                col_idx.push(r + k);
                vals.push(-1.0);
            }
            row_ptr.push(col_idx.len());
        }
    }
    Csr { n, row_ptr, col_idx, vals }
}

/// y = A·x for a full CSR matrix.
pub fn spmv(a: &Csr, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), a.n);
    assert_eq!(y.len(), a.n);
    for r in 0..a.n {
        let mut acc = 0.0;
        for k in a.row_ptr[r]..a.row_ptr[r + 1] {
            acc += a.vals[k] * x[a.col_idx[k]];
        }
        y[r] = acc;
    }
}

pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

pub fn norm2(v: &[f64]) -> f64 {
    dot(v, v).sqrt()
}

/// Residual history of a CG run.
#[derive(Clone, Debug)]
pub struct CgTrace {
    pub iterations: usize,
    pub residuals: Vec<f64>,
    pub converged: bool,
}

/// Conjugate Gradient ([25]): solve A·x = b to `tol` (relative), at
/// most `max_iters` iterations.  `x` holds the initial guess and the
/// solution.
pub fn cg(a: &Csr, b: &[f64], x: &mut [f64], tol: f64, max_iters: usize) -> CgTrace {
    let n = a.n;
    let mut r = vec![0.0; n];
    let mut ax = vec![0.0; n];
    spmv(a, x, &mut ax);
    for i in 0..n {
        r[i] = b[i] - ax[i];
    }
    let bnorm = norm2(b).max(f64::MIN_POSITIVE);
    let mut p = r.clone();
    let mut rr = dot(&r, &r);
    let mut residuals = vec![rr.sqrt() / bnorm];
    let mut ap = vec![0.0; n];
    for it in 0..max_iters {
        if residuals.last().unwrap() < &tol {
            return CgTrace { iterations: it, residuals, converged: true };
        }
        spmv(a, &p, &mut ap);
        let alpha = rr / dot(&p, &ap);
        axpy(alpha, &p, x);
        axpy(-alpha, &ap, &mut r);
        let rr_new = dot(&r, &r);
        let beta = rr_new / rr;
        rr = rr_new;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        residuals.push(rr.sqrt() / bnorm);
    }
    let converged = residuals.last().unwrap() < &tol;
    CgTrace { iterations: max_iters, residuals, converged }
}

/// One explicit CG step (mirrors the L2 JAX `cg_step` executed through
/// PJRT — the cross-layer equivalence tests compare the two).
/// State: (x, r, p, rr); returns the updated state.
#[allow(clippy::type_complexity)]
pub fn cg_step(
    a: &Csr,
    x: &[f64],
    r: &[f64],
    p: &[f64],
    rr: f64,
) -> (Vec<f64>, Vec<f64>, Vec<f64>, f64) {
    let n = a.n;
    let mut ap = vec![0.0; n];
    spmv(a, p, &mut ap);
    let alpha = rr / dot(p, &ap);
    let mut x2 = x.to_vec();
    axpy(alpha, p, &mut x2);
    let mut r2 = r.to_vec();
    axpy(-alpha, &ap, &mut r2);
    let rr2 = dot(&r2, &r2);
    let beta = rr2 / rr;
    let p2: Vec<f64> = r2.iter().zip(p).map(|(ri, pi)| ri + beta * pi).collect();
    (x2, r2, p2, rr2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laplacian_1d_structure() {
        let a = laplacian_1d(5);
        a.validate().unwrap();
        assert_eq!(a.nnz(), 13); // 3*5 - 2
        let x = vec![1.0; 5];
        let mut y = vec![0.0; 5];
        spmv(&a, &x, &mut y);
        assert_eq!(y, vec![1.0, 0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn laplacian_2d_structure() {
        let a = laplacian_2d(4);
        a.validate().unwrap();
        assert_eq!(a.n, 16);
        // interior point has 5 entries, corner 3.
        let row_nnz: Vec<usize> =
            (0..16).map(|r| a.row_ptr[r + 1] - a.row_ptr[r]).collect();
        assert_eq!(row_nnz[0], 3);
        assert_eq!(row_nnz[5], 5);
    }

    #[test]
    fn cg_solves_laplacian() {
        let a = laplacian_2d(8);
        let n = a.n;
        let xs: Vec<f64> = (0..n).map(|i| ((i * 37) % 11) as f64 / 11.0).collect();
        let mut b = vec![0.0; n];
        spmv(&a, &xs, &mut b);
        let mut x = vec![0.0; n];
        let trace = cg(&a, &b, &mut x, 1e-10, 1000);
        assert!(trace.converged, "CG did not converge: {:?}", trace.residuals.last());
        for (xi, xsi) in x.iter().zip(&xs) {
            assert!((xi - xsi).abs() < 1e-7, "{xi} vs {xsi}");
        }
    }

    #[test]
    fn cg_residuals_monotone_enough() {
        let a = laplacian_1d(64);
        let b = vec![1.0; 64];
        let mut x = vec![0.0; 64];
        let trace = cg(&a, &b, &mut x, 1e-12, 200);
        assert!(trace.converged);
        let first = trace.residuals[0];
        let last = *trace.residuals.last().unwrap();
        assert!(last < first * 1e-10);
    }

    #[test]
    fn cg_step_matches_full_cg() {
        // Drive cg_step manually and compare with cg()'s residuals.
        let a = laplacian_2d(5);
        let n = a.n;
        let b = vec![1.0; n];
        let mut x = vec![0.0; n];
        let r = b.clone(); // x0 = 0 → r0 = b
        let p = r.clone();
        let rr = dot(&r, &r);
        let (x1, r1, p1, rr1) = cg_step(&a, &x, &r, &p, rr);
        let (_x2, _r2, _p2, rr2) = cg_step(&a, &x1, &r1, &p1, rr1);
        let trace = cg(&a, &b, &mut x, 1e-30, 2);
        let bn = norm2(&b);
        assert!((rr1.sqrt() / bn - trace.residuals[1]).abs() < 1e-12);
        assert!((rr2.sqrt() / bn - trace.residuals[2]).abs() < 1e-12);
    }

    #[test]
    fn row_slice_spmv_matches_global() {
        let a = laplacian_2d(6);
        let n = a.n;
        let x: Vec<f64> = (0..n).map(|i| (i as f64).cos()).collect();
        let mut y = vec![0.0; n];
        spmv(&a, &x, &mut y);
        // Split rows over 4 shards and compare.
        let mut y2 = vec![0.0; n];
        let bounds = [0, 9, 18, 27, n];
        for w in bounds.windows(2) {
            let shard = a.row_slice(w[0], w[1]);
            let mut part = vec![0.0; shard.rows()];
            shard.spmv(&x, &mut part);
            y2[w[0]..w[1]].copy_from_slice(&part);
        }
        assert_eq!(y, y2);
    }

    #[test]
    fn validate_catches_corruption() {
        let mut a = laplacian_1d(4);
        a.col_idx[0] = 99;
        assert!(a.validate().is_err());
        let mut b = laplacian_1d(4);
        b.row_ptr[2] = 0;
        assert!(b.validate().is_err());
    }
}
