//! Block-ELL sparse format — the Rust mirror of the L1 kernel's input
//! layout (`python/compile/kernels/spmv_ell.py`).
//!
//! The matrix is cut into `BR×BC` dense blocks; each block row stores
//! exactly `K` blocks (zero-padded) plus their block-column indices.
//! [`EllMatrix::from_csr`] converts any [`Csr`](super::Csr) matrix;
//! [`EllMatrix::laplacian_2d`] builds the grid problem with the exact
//! slot layout of the Python generator, so the AOT-compiled CG step
//! and the Rust CG run on bitwise-identical operands.

use super::Csr;

/// A block-ELL matrix in the kernel's memory layout.
#[derive(Clone, Debug, PartialEq)]
pub struct EllMatrix {
    pub nbr: usize,
    pub k: usize,
    pub br: usize,
    pub bc: usize,
    /// Row-major (nbr, K, BR, BC).
    pub data: Vec<f32>,
    /// Row-major (nbr, K).
    pub idx: Vec<i32>,
}

impl EllMatrix {
    pub fn n_rows(&self) -> usize {
        self.nbr * self.br
    }

    /// Convert a CSR matrix.  `k_hint = None` sizes K to the densest
    /// block row; a given K must fit (panics otherwise).
    pub fn from_csr(a: &Csr, br: usize, bc: usize, k_hint: Option<usize>) -> EllMatrix {
        assert!(a.n % br == 0 && a.n % bc == 0, "n must be divisible by BR and BC");
        let nbr = a.n / br;
        let nbc = a.n / bc;
        // Pass 1: which block columns does each block row touch?
        let mut touched: Vec<Vec<usize>> = vec![Vec::new(); nbr];
        for i in 0..nbr {
            let mut mask = vec![false; nbc];
            for r in (i * br)..((i + 1) * br) {
                for kk in a.row_ptr[r]..a.row_ptr[r + 1] {
                    mask[a.col_idx[kk] / bc] = true;
                }
            }
            touched[i] = (0..nbc).filter(|&c| mask[c]).collect();
        }
        let kmax = touched.iter().map(|t| t.len()).max().unwrap_or(0).max(1);
        let k = match k_hint {
            Some(k) => {
                assert!(k >= kmax, "K={k} too small: densest block row needs {kmax}");
                k
            }
            None => kmax,
        };
        // Pass 2: scatter values into the dense blocks.
        let mut data = vec![0.0f32; nbr * k * br * bc];
        let mut idx = vec![0i32; nbr * k];
        for i in 0..nbr {
            let slot_of = |c: usize| touched[i].iter().position(|&t| t == c).unwrap();
            for (s, &c) in touched[i].iter().enumerate() {
                idx[i * k + s] = c as i32;
            }
            for r in (i * br)..((i + 1) * br) {
                for kk in a.row_ptr[r]..a.row_ptr[r + 1] {
                    let c = a.col_idx[kk];
                    let s = slot_of(c / bc);
                    let off = ((i * k + s) * br + (r - i * br)) * bc + (c % bc);
                    data[off] += a.vals[kk] as f32;
                }
            }
        }
        EllMatrix { nbr, k, br, bc, data, idx }
    }

    /// The grid×grid 5-point Laplacian with BR = BC = grid and K = 3 —
    /// slot layout identical to `ref.laplacian_2d_block_ell` in Python
    /// (slot 0: block col i−1, slot 1: diagonal, slot 2: i+1).
    pub fn laplacian_2d(grid: usize) -> EllMatrix {
        let (nbr, k, br, bc) = (grid, 3usize, grid, grid);
        let mut data = vec![0.0f32; nbr * k * br * bc];
        let mut idx = vec![0i32; nbr * k];
        let put = |data: &mut [f32], i: usize, s: usize, r: usize, c: usize, v: f32| {
            data[((i * k + s) * br + r) * bc + c] += v;
        };
        for i in 0..nbr {
            if i > 0 {
                idx[i * k] = (i - 1) as i32;
                for r in 0..br {
                    put(&mut data, i, 0, r, r, -1.0);
                }
            }
            idx[i * k + 1] = i as i32;
            for r in 0..br {
                put(&mut data, i, 1, r, r, 4.0);
                if r > 0 {
                    put(&mut data, i, 1, r, r - 1, -1.0);
                }
                if r + 1 < br {
                    put(&mut data, i, 1, r, r + 1, -1.0);
                }
            }
            if i + 1 < nbr {
                idx[i * k + 2] = (i + 1) as i32;
                for r in 0..br {
                    put(&mut data, i, 2, r, r, -1.0);
                }
            }
        }
        EllMatrix { nbr, k, br, bc, data, idx }
    }

    /// Reference SpMV over the block-ELL layout (f32, mirrors the
    /// kernel semantics including duplicate-slot accumulation).
    pub fn spmv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len() % self.bc, 0);
        let mut y = vec![0.0f32; self.n_rows()];
        for i in 0..self.nbr {
            for s in 0..self.k {
                let col = self.idx[i * self.k + s] as usize;
                for r in 0..self.br {
                    let base = ((i * self.k + s) * self.br + r) * self.bc;
                    let mut acc = 0.0f32;
                    for c in 0..self.bc {
                        acc += self.data[base + c] * x[col * self.bc + c];
                    }
                    y[i * self.br + r] += acc;
                }
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::super::{laplacian_2d, spmv};
    use super::*;

    #[test]
    fn from_csr_roundtrips_spmv() {
        let a = laplacian_2d(8);
        let e = EllMatrix::from_csr(&a, 8, 8, None);
        assert_eq!(e.k, 3, "5-point stencil with BR=grid needs K=3");
        let x: Vec<f64> = (0..a.n).map(|i| (i as f64 * 0.37).sin()).collect();
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let mut y = vec![0.0; a.n];
        spmv(&a, &x, &mut y);
        let ye = e.spmv(&xf);
        for (a, b) in y.iter().zip(&ye) {
            assert!((a - *b as f64).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn builtin_laplacian_matches_csr_conversion() {
        let direct = EllMatrix::laplacian_2d(6);
        let converted = EllMatrix::from_csr(&laplacian_2d(6), 6, 6, Some(3));
        // Same SpMV results (slot ordering may differ only in padding).
        let x: Vec<f32> = (0..36).map(|i| (i as f32).cos()).collect();
        let y1 = direct.spmv(&x);
        let y2 = converted.spmv(&x);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    #[should_panic(expected = "too small")]
    fn k_hint_too_small_panics() {
        EllMatrix::from_csr(&laplacian_2d(4), 4, 4, Some(1));
    }

    #[test]
    fn padding_slots_are_zero_blocks() {
        // First block row has no i-1 neighbour: slot 0 must be zeros.
        let e = EllMatrix::laplacian_2d(4);
        let first_block = &e.data[0..16];
        assert!(first_block.iter().all(|&v| v == 0.0));
        assert_eq!(e.idx[0], 0);
    }
}
