//! # proteo-rma
//!
//! Reproduction of **"Dynamic reconfiguration for malleable
//! applications using RMA"** (Martín-Álvarez, Aliaga, Castillo —
//! CS.DC 2025) as a three-layer Rust + JAX + Pallas system.
//!
//! The paper extends the Proteo/MaM malleability framework with
//! one-sided (MPI-RMA) data-redistribution methods and evaluates them
//! against the collective (`MPI_Alltoallv`) baseline on a synthetic
//! Conjugate-Gradient application.  This crate rebuilds the entire
//! stack on a deterministic discrete-event cluster simulator:
//!
//! * [`simcluster`] — the DES engine (virtual clock, simulated
//!   processes as real threads),
//! * [`netmodel`] — calibrated α-β network/NIC/registration cost model
//!   of the paper's 8-node InfiniBand EDR testbed,
//! * [`simmpi`] — an MPI-4-like runtime (p2p, collectives, passive-
//!   target RMA, dynamic process spawning) on top of the DES,
//! * [`mam`] — the Malleability Module: MaM's process management
//!   (*Merge*), block data redistribution (Algorithm 1), the
//!   redistribution methods (COL, RMA-Lock, RMA-Lockall) and
//!   strategies (Blocking, Non-Blocking, Wait Drains, Threading),
//! * [`sam`] — the Synthetic Application Module emulating CG,
//! * [`rms`] — a miniature resource manager driving reconfigurations,
//! * [`proteo`] — experiment runner implementing §V's methodology
//!   (Eq. 1–3) and the figure harnesses,
//! * [`linalg`] — real CSR/CG substrate for end-to-end validation,
//! * [`runtime`] — PJRT loader executing the AOT-compiled JAX/Pallas
//!   CG step from `artifacts/` on the Rust side,
//! * [`monitor`], [`config`], [`util`] — metrics, config system and
//!   self-contained substrates (JSON, CLI, bench harness, property
//!   testing, PRNG, stats),
//! * [`analysis`] — `proteo audit`: the determinism & concurrency
//!   lint engine guarding the byte-determinism contract.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod analysis;
pub mod config;
pub mod experiments;
pub mod linalg;
pub mod mam;
pub mod monitor;
pub mod netmodel;
pub mod proteo;
pub mod rms;
pub mod runtime;
pub mod sam;
pub mod simcluster;
pub mod simmpi;
pub mod util;
