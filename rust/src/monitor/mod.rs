//! Metrics recording — Proteo's monitoring submodule.
//!
//! The world owns one [`Metrics`] instance; simulated code records
//! counters, time marks and series into it, and the experiment
//! harnesses (`experiments/`) read them back to produce the paper's
//! figures (redistribution time R, iteration counts N_it, per-iteration
//! times for ω, …).

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Thread-safe-by-context metrics store (lives inside the world mutex).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    counters: BTreeMap<String, f64>,
    series: BTreeMap<String, Vec<f64>>,
    marks: BTreeMap<String, f64>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    // ------------------------------------------------------- counters

    pub fn add_counter(&mut self, name: &str, delta: f64) {
        *self.counters.entry(name.to_string()).or_insert(0.0) += delta;
    }

    pub fn set_counter(&mut self, name: &str, value: f64) {
        self.counters.insert(name.to_string(), value);
    }

    pub fn counter(&self, name: &str) -> Option<f64> {
        self.counters.get(name).copied()
    }

    // ---------------------------------------------------------- marks

    /// Record a named instant (virtual time).
    pub fn mark(&mut self, name: &str, t: f64) {
        self.marks.insert(name.to_string(), t);
    }

    pub fn mark_at(&self, name: &str) -> Option<f64> {
        self.marks.get(name).copied()
    }

    /// Keep the earliest of the recorded and new instant (first rank to
    /// reach a phase defines its start).
    pub fn mark_min(&mut self, name: &str, t: f64) {
        let e = self.marks.entry(name.to_string()).or_insert(f64::INFINITY);
        if t < *e {
            *e = t;
        }
    }

    /// Keep the latest of the recorded and new instant (last rank to
    /// finish a phase defines its end).
    pub fn mark_max(&mut self, name: &str, t: f64) {
        let e = self.marks.entry(name.to_string()).or_insert(f64::NEG_INFINITY);
        if t > *e {
            *e = t;
        }
    }

    /// Duration between two marks, if both exist.
    pub fn span(&self, start: &str, end: &str) -> Option<f64> {
        Some(self.mark_at(end)? - self.mark_at(start)?)
    }

    // --------------------------------------------------------- series

    pub fn push_series(&mut self, name: &str, v: f64) {
        self.series.entry(name.to_string()).or_default().push(v);
    }

    pub fn series(&self, name: &str) -> Option<&[f64]> {
        self.series.get(name).map(|v| v.as_slice())
    }

    pub fn series_len(&self, name: &str) -> usize {
        self.series.get(name).map_or(0, |v| v.len())
    }

    /// Remove everything (between repetitions).
    pub fn clear(&mut self) {
        self.counters.clear();
        self.series.clear();
        self.marks.clear();
    }

    /// Export as JSON for the experiment reports.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert(
            "counters".to_string(),
            Json::Obj(self.counters.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect()),
        );
        obj.insert(
            "marks".to_string(),
            Json::Obj(self.marks.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect()),
        );
        obj.insert(
            "series".to_string(),
            Json::Obj(
                self.series
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::arr_f64(v)))
                    .collect(),
            ),
        );
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.add_counter("x", 1.0);
        m.add_counter("x", 2.5);
        assert_eq!(m.counter("x"), Some(3.5));
        assert_eq!(m.counter("y"), None);
        m.set_counter("x", 7.0);
        assert_eq!(m.counter("x"), Some(7.0));
    }

    #[test]
    fn marks_and_spans() {
        let mut m = Metrics::new();
        m.mark("start", 1.0);
        m.mark("end", 3.5);
        assert_eq!(m.span("start", "end"), Some(2.5));
        assert_eq!(m.span("start", "missing"), None);
    }

    #[test]
    fn series_collects() {
        let mut m = Metrics::new();
        m.push_series("it", 0.1);
        m.push_series("it", 0.2);
        assert_eq!(m.series("it").unwrap(), &[0.1, 0.2]);
        assert_eq!(m.series_len("it"), 2);
        assert_eq!(m.series_len("other"), 0);
    }

    #[test]
    fn json_export_roundtrips() {
        let mut m = Metrics::new();
        m.add_counter("c", 2.0);
        m.mark("t0", 0.5);
        m.push_series("s", 9.0);
        let j = m.to_json();
        assert_eq!(j.get_path("counters.c").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get_path("marks.t0").unwrap().as_f64(), Some(0.5));
        assert_eq!(j.get_path("series.s").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn clear_resets() {
        let mut m = Metrics::new();
        m.add_counter("c", 1.0);
        m.push_series("s", 1.0);
        m.mark("m", 1.0);
        m.clear();
        assert!(m.counter("c").is_none());
        assert!(m.series("s").is_none());
        assert!(m.mark_at("m").is_none());
    }
}
