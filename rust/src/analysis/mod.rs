//! `proteo audit` — the determinism & concurrency lint engine.
//!
//! Every result in this reproduction rests on one invariant: simulated
//! runs are **byte-deterministic** — knobs-off bit-identity across
//! PRs, rank agreement without synchronization, queue-swap
//! equivalence.  The property tests *assert* it; this module
//! *prevents* the easy ways of silently breaking it.  A lightweight,
//! syn-free scanner (the build is offline — no parser crates) walks
//! `rust/src/**` and enforces the contract as named, suppressible
//! lints:
//!
//! | lint | guards against |
//! |------|----------------|
//! | `det::hashmap-iter-escapes` | std hash-container order escaping into virtual time or reports |
//! | `det::wall-clock-in-sim` | `Instant`/`SystemTime` outside [`crate::util::wallclock`] |
//! | `det::unseeded-rng` | entropy-seeded RNGs (`thread_rng`, `OsRng`, …) |
//! | `conc::bare-thread-spawn` | OS threads outside the `simcluster::engine` worker pool |
//! | `conc::lock-order` | acquisitions violating the world → worker_pool hierarchy |
//! | `api::deprecated-shim` | callers routing through `#[deprecated]` lifecycle shims |
//! | `audit::stale-allow` | suppressions that hide nothing (or lack a reason) |
//!
//! A finding can be suppressed in place with
//! `// audit:allow(lint-name, reason)` on the offending line or the
//! line directly above; the reason is mandatory and a directive that
//! no longer suppresses anything is itself flagged
//! (`audit::stale-allow`), so the escape hatch cannot rot.
//!
//! Run `proteo audit` for a report, `proteo audit --deny` as the CI
//! gate (nonzero exit on any finding).  The scanner works on a *code
//! view* with comments/strings blanked (see [`source`]), so lints
//! never fire on prose, and its output is sorted — the audit is as
//! deterministic as the code it checks.

pub mod lints;
pub mod source;

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::path::{Path, PathBuf};

pub use lints::{
    rationale, BARE_SPAWN, DEPRECATED_SHIM, HASHMAP_ITER, LINTS, LOCK_ORDER, STALE_ALLOW,
    UNSEEDED_RNG, WALL_CLOCK,
};
use source::SourceFile;

/// One lint hit: `file:line: [lint] message`.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub lint: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, out: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(out, "{}:{}: [{}] {}", self.file, self.line, self.lint, self.message)
    }
}

/// Audit in-memory sources: `(name, content)` pairs.  Returns the
/// surviving findings sorted by `(file, line, lint, message)` —
/// independent of the order files are passed in.
pub fn audit_sources(files: &[(String, String)]) -> Vec<Finding> {
    let parsed: Vec<SourceFile> = files.iter().map(|(n, t)| SourceFile::parse(n, t)).collect();

    // Crate-wide pass: every #[deprecated] fn (name -> declaring
    // module stems), each file's own shim spans (shims may delegate
    // through each other), and the names that also have non-deprecated
    // definitions (ambiguous without type info; see lints.rs).
    let mut dep_stems: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    let mut dep_spans: BTreeMap<String, Vec<lints::DeprecatedFn>> = BTreeMap::new();
    for f in &parsed {
        let d = lints::deprecated_fns(f);
        let stem = lints::module_stem(&f.name);
        for x in &d {
            dep_stems.entry(x.name.clone()).or_default().insert(stem.clone());
        }
        dep_spans.insert(f.name.clone(), d);
    }
    let mut nondep: BTreeSet<String> = BTreeSet::new();
    for f in &parsed {
        let own = &dep_spans[&f.name];
        for (name, line) in lints::fn_defs(f) {
            if !own.iter().any(|d| d.span.0 <= line && line <= d.span.1) {
                nondep.insert(name);
            }
        }
    }

    let mut findings: Vec<Finding> = Vec::new();
    for f in &parsed {
        let own = &dep_spans[&f.name];
        let mut raw = Vec::new();
        raw.extend(lints::lint_hash_containers(f));
        raw.extend(lints::lint_wall_clock(f));
        raw.extend(lints::lint_unseeded_rng(f));
        raw.extend(lints::lint_bare_spawn(f));
        raw.extend(lints::lint_lock_order(f));
        raw.extend(lints::lint_deprecated_callers(f, &dep_stems, &nondep, own));
        // In-place suppression (marks the directives it uses).
        findings.extend(raw.into_iter().filter(|x| !f.allowed(x.lint, x.line)));
        // Directive hygiene: reasons are mandatory, staleness is a
        // finding.  Deliberately not suppressible by itself.
        for a in &f.allows {
            if a.reason.is_empty() {
                findings.push(Finding {
                    file: f.name.clone(),
                    line: a.line,
                    lint: STALE_ALLOW,
                    message: format!("audit:allow({}) lacks its mandatory reason", a.lint),
                });
            } else if !a.used.get() {
                findings.push(Finding {
                    file: f.name.clone(),
                    line: a.line,
                    lint: STALE_ALLOW,
                    message: format!(
                        "audit:allow({}, {}) suppresses nothing here; remove it",
                        a.lint, a.reason
                    ),
                });
            }
        }
    }
    findings.sort();
    findings.dedup();
    findings
}

/// Audit every `.rs` file under `root`, in sorted path order.  File
/// names in the findings are `root`-relative with `/` separators.
pub fn audit_tree(root: &Path) -> Result<Vec<Finding>, String> {
    let mut paths: Vec<PathBuf> = Vec::new();
    collect_rs(root, &mut paths)?;
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for p in &paths {
        let text =
            std::fs::read_to_string(p).map_err(|e| format!("read {}: {e}", p.display()))?;
        let rel = p.strip_prefix(root).unwrap_or(p);
        files.push((rel.display().to_string().replace('\\', "/"), text));
    }
    Ok(audit_sources(&files))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("read dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read dir {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn audit_one(name: &str, src: &str) -> Vec<Finding> {
        audit_sources(&[(name.to_string(), src.to_string())])
    }

    #[test]
    fn clean_source_has_no_findings() {
        let src = concat!(
            "use std::collections::BTreeMap;\n",
            "fn f() -> BTreeMap<u8, u8> { BTreeMap::new() }\n"
        );
        assert!(audit_one("a.rs", src).is_empty());
    }

    #[test]
    fn hash_container_in_string_or_comment_never_fires() {
        let src = "// a HashMap joke\nfn f() { let s = \"HashSet\"; let _ = s; }\n";
        assert!(audit_one("a.rs", src).is_empty());
    }

    #[test]
    fn wallclock_module_is_the_single_allowed_clock_site() {
        let src = "use std::time::Instant;\n";
        assert!(audit_one("util/wallclock.rs", src).is_empty());
        let hit = audit_one("simmpi/world.rs", src);
        assert_eq!(hit.len(), 1);
        assert_eq!(hit[0].lint, WALL_CLOCK);
    }

    #[test]
    fn allow_suppresses_and_staleness_is_flagged() {
        let ok = "// audit:allow(det::hashmap-iter-escapes, ok)\nuse std::collections::HashMap;\n";
        assert!(audit_one("a.rs", ok).is_empty());
        let stale = "// audit:allow(det::hashmap-iter-escapes, nothing here)\nfn f() {}\n";
        let hit = audit_one("a.rs", stale);
        assert_eq!(hit.len(), 1);
        assert_eq!(hit[0].lint, STALE_ALLOW);
        assert_eq!(hit[0].line, 1);
    }

    #[test]
    fn findings_sort_independently_of_file_order() {
        let a = ("a.rs".to_string(), "use std::collections::HashMap;\n".to_string());
        let b = ("b.rs".to_string(), "use std::time::Instant;\n".to_string());
        let fwd = audit_sources(&[a.clone(), b.clone()]);
        let rev = audit_sources(&[b, a]);
        assert_eq!(fwd, rev);
        assert_eq!(fwd.len(), 2);
    }

    #[test]
    fn every_lint_has_a_rationale() {
        for (name, why) in LINTS {
            assert!(rationale(name).is_some(), "{name}");
            assert!(!why.is_empty());
        }
        assert!(rationale("not-a-lint").is_none());
    }
}
