//! The individual lint passes.
//!
//! Every pass is line-oriented over a [`SourceFile`]'s code view (see
//! [`super::source`]), reports 1-based `file:line` positions, and is a
//! pure function of the source text — the audit itself must be as
//! deterministic as the simulator it guards.

use std::collections::{BTreeMap, BTreeSet};

use super::source::{ident_hits, SourceFile};
use super::Finding;

/// `HashMap`/`HashSet` iteration order is seeded per-process
/// (`RandomState`), so any use inside the simulator risks leaking
/// nondeterministic order into virtual time, counters, or JSON.  The
/// lint conservatively flags *every* use of the std hash containers:
/// the crate's keyed tables are `BTreeMap`/`BTreeSet` by contract, and
/// a genuinely order-free use can carry an `audit:allow`.
pub const HASHMAP_ITER: &str = "det::hashmap-iter-escapes";

/// Wall-clock reads (`Instant`, `SystemTime`) differ across machines
/// and runs; sim-path durations must come from virtual time.  The only
/// allowed module is `util::wallclock`, the gateway harness code uses
/// for soft `wall_s` metrics.
pub const WALL_CLOCK: &str = "det::wall-clock-in-sim";

/// Entropy-seeded RNGs (`thread_rng`, `OsRng`, `from_entropy`,
/// `RandomState`, `getrandom`) make runs unrepeatable.  All
/// randomness flows from `util::rng` seeded by the `RunSpec`.
pub const UNSEEDED_RNG: &str = "det::unseeded-rng";

/// OS threads spawned outside the pooled worker in
/// `simcluster::engine` escape the engine's scheduling discipline
/// (bounded pool, deterministic handoff) and TSan coverage.
pub const BARE_SPAWN: &str = "conc::bare-thread-spawn";

/// Declared lock hierarchy: the world mutex (`world` / `w`) is
/// acquired *before* the worker-pool mutex (`worker_pool` / `pool`),
/// and neither is acquired re-entrantly.  Acquiring against the order
/// deadlocks under contention.
pub const LOCK_ORDER: &str = "conc::lock-order";

/// Calls routed through `#[deprecated]` lifecycle shims (PR 7) keep
/// dead API surface alive; call the `*_with` opts-struct entrypoints.
pub const DEPRECATED_SHIM: &str = "api::deprecated-shim";

/// An `audit:allow` that no longer suppresses anything (or lacks a
/// reason) is itself a defect: suppressions must stay auditable and
/// minimal.
pub const STALE_ALLOW: &str = "audit::stale-allow";

/// Every lint the pass knows, with its rationale.
pub const LINTS: &[(&str, &str)] = &[
    (HASHMAP_ITER, "hash containers iterate in RandomState order; use BTreeMap/BTreeSet"),
    (WALL_CLOCK, "Instant/SystemTime vary per host; only util::wallclock may read them"),
    (UNSEEDED_RNG, "entropy-seeded RNGs are unrepeatable; seed util::rng from the RunSpec"),
    (BARE_SPAWN, "threads outside the engine worker pool escape deterministic handoff"),
    (LOCK_ORDER, "order is world before worker_pool, never re-entrant; else deadlock"),
    (DEPRECATED_SHIM, "shims last one transition PR; call the *_with opts entrypoints"),
    (STALE_ALLOW, "audit:allow needs a reason and a live finding; stale ones rot"),
];

/// Rationale for a lint name, if known.
pub fn rationale(lint: &str) -> Option<&'static str> {
    LINTS.iter().find(|(n, _)| *n == lint).map(|(_, r)| *r)
}

fn file_is(f: &SourceFile, suffix: &str) -> bool {
    f.name == suffix || f.name.ends_with(&format!("/{suffix}"))
}

fn word_lint(f: &SourceFile, words: &[&str], lint: &'static str, what: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, line) in f.code.iter().enumerate() {
        for w in words {
            if !ident_hits(line, w).is_empty() {
                out.push(Finding {
                    file: f.name.clone(),
                    line: i + 1,
                    lint,
                    message: format!("{what} `{w}`"),
                });
            }
        }
    }
    out
}

pub fn lint_hash_containers(f: &SourceFile) -> Vec<Finding> {
    word_lint(f, &["HashMap", "HashSet"], HASHMAP_ITER, "std hash container")
}

pub fn lint_wall_clock(f: &SourceFile) -> Vec<Finding> {
    if file_is(f, "util/wallclock.rs") {
        return Vec::new();
    }
    word_lint(f, &["Instant", "SystemTime"], WALL_CLOCK, "wall-clock type")
}

pub fn lint_unseeded_rng(f: &SourceFile) -> Vec<Finding> {
    let words = ["thread_rng", "from_entropy", "OsRng", "getrandom", "RandomState", "SmallRng"];
    word_lint(f, &words, UNSEEDED_RNG, "entropy-seeded RNG")
}

pub fn lint_bare_spawn(f: &SourceFile) -> Vec<Finding> {
    if file_is(f, "simcluster/engine.rs") {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (i, line) in f.code.iter().enumerate() {
        if line.contains("thread::spawn") || line.contains("thread::Builder") {
            out.push(Finding {
                file: f.name.clone(),
                line: i + 1,
                lint: BARE_SPAWN,
                message: "OS thread outside the simcluster::engine worker pool".to_string(),
            });
        }
    }
    out
}

/// Declared hierarchy rank of a mutex, from the receiver expression's
/// final path segment.  Lower ranks are acquired first.
fn lock_rank(receiver: &str) -> Option<(u8, &'static str)> {
    match receiver {
        "world" | "w" => Some((1, "world")),
        "worker_pool" | "pool" => Some((2, "worker_pool")),
        _ => None,
    }
}

/// The receiver's final identifier segment before `.lock()` at byte
/// offset `at` in `line` (e.g. `self.world.lock()` → `world`,
/// `worker_pool().lock()` → `worker_pool`).
fn lock_receiver(line: &str, at: usize) -> String {
    let b = line.as_bytes();
    let mut end = at;
    while end > 0 && b[end - 1] == b')' {
        // Strip a trailing call: find its matching open paren.
        let mut depth = 0usize;
        let mut j = end;
        while j > 0 {
            j -= 1;
            match b[j] {
                b')' => depth += 1,
                b'(' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
        }
        end = j;
    }
    let mut start = end;
    while start > 0 && (b[start - 1] == b'_' || b[start - 1].is_ascii_alphanumeric()) {
        start -= 1;
    }
    line[start..end].to_string()
}

struct Hold {
    name: String,
    rank: u8,
    label: &'static str,
    depth: i32,
}

pub fn lint_lock_order(f: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut depth: i32 = 0;
    let mut holds: Vec<Hold> = Vec::new();
    let mut barriers: Vec<i32> = Vec::new();
    let mut prev_nonws = b' ';
    for (i, line) in f.code.iter().enumerate() {
        // 1. Acquisition events on this line, checked against holds
        //    visible at the current depth (closure bodies run later on
        //    other activities, so an enclosing closure is a barrier).
        let floor = barriers.last().copied().unwrap_or(i32::MIN);
        let mut from = 0;
        while let Some(p) = line[from..].find(".lock()") {
            let at = from + p;
            if let Some((rank, label)) = lock_rank(&lock_receiver(line, at)) {
                for h in holds.iter().filter(|h| h.depth >= floor) {
                    if h.rank >= rank {
                        out.push(Finding {
                            file: f.name.clone(),
                            line: i + 1,
                            lint: LOCK_ORDER,
                            message: format!(
                                "acquires `{label}` while `{}` is held by `{}`",
                                h.label, h.name
                            ),
                        });
                    }
                }
            }
            from = at + ".lock()".len();
        }
        // 2. Guard bindings: `let [mut] NAME = <recv>.lock().unwrap();`
        //    hold until their block closes or an explicit drop.
        let t = line.trim();
        if t.starts_with("let ") && t.ends_with(".lock().unwrap();") {
            let rest = t["let ".len()..].trim_start_matches("mut ").trim_start();
            let name: String =
                rest.chars().take_while(|c| c.is_ascii_alphanumeric() || *c == '_').collect();
            let at = line.find(".lock()").expect("suffix-checked");
            if let Some((rank, label)) = lock_rank(&lock_receiver(line, at)) {
                if !name.is_empty() {
                    holds.push(Hold { name, rank, label, depth });
                }
            }
        }
        // 3. Explicit releases.
        holds.retain(|h| !line.contains(&format!("drop({})", h.name)));
        // 4. Brace and closure-barrier tracking.
        for &c in line.as_bytes() {
            match c {
                b'{' => {
                    depth += 1;
                    if prev_nonws == b'|' {
                        barriers.push(depth);
                    }
                }
                b'}' => {
                    depth -= 1;
                    holds.retain(|h| h.depth <= depth);
                    barriers.retain(|&b| b <= depth);
                }
                b' ' | b'\t' => continue,
                _ => {}
            }
            prev_nonws = c;
        }
    }
    out
}

/// A `#[deprecated]` function: its name and body line span (1-based,
/// inclusive, covering signature through closing brace).
pub struct DeprecatedFn {
    pub name: String,
    pub span: (usize, usize),
}

/// Collect the `#[deprecated]` functions declared in `f`.
pub fn deprecated_fns(f: &SourceFile) -> Vec<DeprecatedFn> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < f.code.len() {
        if !f.code[i].contains("#[deprecated") {
            i += 1;
            continue;
        }
        // Find the `fn` the attribute decorates.
        let mut j = i + 1;
        let mut name = String::new();
        while j < f.code.len() {
            if let Some(p) = f.code[j].find("fn ") {
                let rest = &f.code[j][p + 3..];
                name = rest
                    .chars()
                    .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                    .collect();
                break;
            }
            j += 1;
        }
        if name.is_empty() {
            i += 1;
            continue;
        }
        // Track braces from the signature line to the body's close.
        let mut depth = 0i32;
        let mut opened = false;
        let mut end = j;
        'body: for (k, line) in f.code.iter().enumerate().skip(j) {
            for &c in line.as_bytes() {
                match c {
                    b'{' => {
                        depth += 1;
                        opened = true;
                    }
                    b'}' => depth -= 1,
                    b';' if !opened => {
                        // Bodyless declaration.
                        end = k;
                        break 'body;
                    }
                    _ => {}
                }
            }
            if opened && depth == 0 {
                end = k;
                break;
            }
        }
        out.push(DeprecatedFn { name, span: (i + 1, end + 1) });
        i = end + 1;
    }
    out
}

/// All `fn NAME` definitions in a file: `(name, 1-based line)`.
pub fn fn_defs(f: &SourceFile) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for (i, line) in f.code.iter().enumerate() {
        let lb = line.as_bytes();
        let mut from = 0;
        while let Some(p) = line[from..].find("fn ") {
            let at = from + p;
            from = at + 3;
            if at > 0 && (lb[at - 1] == b'_' || lb[at - 1].is_ascii_alphanumeric()) {
                continue;
            }
            let name: String = line[at + 3..]
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                out.push((name, i + 1));
            }
        }
    }
    out
}

/// The module name a file defines (`mam/rma.rs` → `rma`,
/// `mam/mod.rs` → `mam`), used to match path-qualified calls.
pub fn module_stem(name: &str) -> String {
    let segs: Vec<&str> = name.trim_end_matches(".rs").split('/').collect();
    match segs.as_slice() {
        [.., parent, "mod"] => (*parent).to_string(),
        [.., last] => (*last).to_string(),
        [] => String::new(),
    }
}

/// Flag calls to crate-wide deprecated shims, excluding the shims' own
/// definitions and bodies (a shim may delegate through another).
///
/// Without type information a bare name is ambiguous when a
/// *non-deprecated* function of the same name also exists (the COL
/// method's `redistribute_blocking` vs the RMA shim of the same name),
/// so the matcher is deliberately one-sided: a path-qualified call
/// (`rma::redistribute_blocking(..)`) is flagged only when the
/// qualifier names a module that declares the deprecated fn, and an
/// unqualified or method call only when no non-deprecated twin exists
/// anywhere in the tree.  False negatives are possible; false
/// positives are not.
pub fn lint_deprecated_callers(
    f: &SourceFile,
    dep_stems: &BTreeMap<String, BTreeSet<String>>,
    nondep: &BTreeSet<String>,
    own: &[DeprecatedFn],
) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, line) in f.code.iter().enumerate() {
        let ln = i + 1;
        if own.iter().any(|d| d.span.0 <= ln && ln <= d.span.1) {
            continue;
        }
        for (name, stems) in dep_stems {
            for at in ident_hits(line, name) {
                let after = line[at + name.len()..].trim_start();
                if !after.starts_with('(') {
                    continue;
                }
                let before = line[..at].trim_end();
                if before.ends_with("fn") {
                    continue;
                }
                let hit = match path_qualifier(line, at) {
                    Some(seg) => stems.contains(&seg),
                    None => !nondep.contains(name),
                };
                if hit {
                    out.push(Finding {
                        file: f.name.clone(),
                        line: ln,
                        lint: DEPRECATED_SHIM,
                        message: format!("call routes through deprecated shim `{name}`"),
                    });
                }
            }
        }
    }
    out
}

/// The path segment directly before `seg::name` at byte offset `at`,
/// if the call is path-qualified.
fn path_qualifier(line: &str, at: usize) -> Option<String> {
    let b = line.as_bytes();
    if at < 2 || b[at - 1] != b':' || b[at - 2] != b':' {
        return None;
    }
    let mut start = at - 2;
    while start > 0 && (b[start - 1] == b'_' || b[start - 1].is_ascii_alphanumeric()) {
        start -= 1;
    }
    Some(line[start..at - 2].to_string())
}
