//! Source preprocessing for the audit pass.
//!
//! The scanner is deliberately **syn-free** (no external parser crates
//! — the build is offline), so every lint works on a *code view* of
//! each file: the original text with comments, string literals, and
//! char literals blanked to spaces, byte-for-byte line-aligned with
//! the original.  Lints that match identifiers (`HashMap`, `Instant`,
//! `thread::spawn`, …) therefore never fire on prose, and brace
//! counting is not confused by `"{"` in strings.
//!
//! Allow directives are extracted from a second, *comment view* of the
//! file (strings blanked, comments kept), so a directive inside a
//! string literal — or this very documentation — never counts.  Doc
//! comments (`///`, `//!`) are prose and are skipped too: only plain
//! `//` comments can carry a directive.

/// A parsed `// audit:allow(lint, reason)` directive.
#[derive(Clone, Debug)]
pub struct Allow {
    /// 1-based line the directive sits on.
    pub line: usize,
    /// The lint name inside the parens (may be unknown; checked later).
    pub lint: String,
    /// The free-text justification.  Empty means malformed — a reason
    /// is mandatory so suppressions stay auditable.
    pub reason: String,
    /// Set when some finding was actually suppressed by this
    /// directive; stale directives are themselves findings.
    pub used: std::cell::Cell<bool>,
}

/// One source file, preprocessed for linting.
pub struct SourceFile {
    /// Path as given (repo- or root-relative), with `/` separators.
    pub name: String,
    /// Code view split into lines (no terminators), parallel to the
    /// original line numbering.
    pub code: Vec<String>,
    /// All allow directives in the file.
    pub allows: Vec<Allow>,
}

impl SourceFile {
    pub fn parse(name: &str, text: &str) -> SourceFile {
        let (view, comments) = views(text);
        let code: Vec<String> = view.lines().map(str::to_string).collect();
        let allows = parse_allows(&comments);
        SourceFile { name: name.replace('\\', "/"), code, allows }
    }

    /// True when an allow directive for `lint` covers `line` (the
    /// directive's own line for trailing comments, or the line
    /// directly below for a directive on its own line).
    pub fn allowed(&self, lint: &str, line: usize) -> bool {
        for a in &self.allows {
            if a.lint == lint && !a.reason.is_empty() && (a.line == line || a.line + 1 == line) {
                a.used.set(true);
                return true;
            }
        }
        false
    }
}

fn is_ident(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// Code view only (test and external convenience).
pub fn code_view(src: &str) -> String {
    views(src).0
}

/// Build the code view and the comment view in one pass.
///
/// * **Code view** — comments, strings and char literals blanked to
///   spaces; every newline preserved, so positions map 1:1.
/// * **Comment view** — strings and char literals blanked, comments
///   kept verbatim (this is where allow directives are parsed from).
///
/// Handles nested block comments, escape sequences, raw strings
/// (`r"…"`, `r#"…"#`, byte variants), and distinguishes lifetimes
/// (`'a`) from char literals (`'x'`).
pub fn views(src: &str) -> (String, String) {
    let b = src.as_bytes();
    let mut code = Vec::with_capacity(b.len());
    let mut com = Vec::with_capacity(b.len());
    let mut i = 0;
    // Emit one byte per view: `both!(code_byte, comment_byte)`.
    macro_rules! both {
        ($code_byte:expr, $com_byte:expr) => {{
            code.push($code_byte);
            com.push($com_byte);
        }};
    }
    while i < b.len() {
        let c = b[i];
        // Line comment: blank in code view, verbatim in comment view.
        if c == b'/' && b.get(i + 1) == Some(&b'/') {
            while i < b.len() && b[i] != b'\n' {
                both!(b' ', b[i]);
                i += 1;
            }
            continue;
        }
        // Block comment (nested): blanked in the code view, kept in
        // the comment view (newlines preserved in both).
        if c == b'/' && b.get(i + 1) == Some(&b'*') {
            let mut depth = 0usize;
            while i < b.len() {
                if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                    depth += 1;
                    both!(b' ', b'/');
                    both!(b' ', b'*');
                    i += 2;
                } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                    depth -= 1;
                    both!(b' ', b'*');
                    both!(b' ', b'/');
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    let keep = if b[i] == b'\n' { b'\n' } else { b' ' };
                    both!(keep, b[i]);
                    i += 1;
                }
            }
            continue;
        }
        // Raw (byte) string: r"…", r#"…"#, br"…", …
        if (c == b'r' || c == b'b') && (i == 0 || !is_ident(b[i - 1])) {
            let mut j = i;
            if b[j] == b'b' && b.get(j + 1) == Some(&b'r') {
                j += 1;
            }
            if b[j] == b'r' {
                let mut k = j + 1;
                let mut hashes = 0usize;
                while b.get(k) == Some(&b'#') {
                    hashes += 1;
                    k += 1;
                }
                if b.get(k) == Some(&b'"') {
                    // Emit the prefix + opening quote verbatim.
                    for &p in &b[i..=k] {
                        both!(p, p);
                    }
                    i = k + 1;
                    'raw: while i < b.len() {
                        if b[i] == b'"' {
                            let close = &b[i + 1..(i + 1 + hashes).min(b.len())];
                            if close.len() == hashes && close.iter().all(|&h| h == b'#') {
                                both!(b'"', b'"');
                                for &h in close {
                                    both!(h, h);
                                }
                                i += 1 + hashes;
                                break 'raw;
                            }
                        }
                        let keep = if b[i] == b'\n' { b'\n' } else { b' ' };
                        both!(keep, keep);
                        i += 1;
                    }
                    continue;
                }
            }
        }
        // Plain (byte) string: blanked in both views.
        if c == b'"' {
            both!(b'"', b'"');
            i += 1;
            while i < b.len() {
                if b[i] == b'\\' && i + 1 < b.len() {
                    both!(b' ', b' ');
                    both!(b' ', b' ');
                    i += 2;
                } else if b[i] == b'"' {
                    both!(b'"', b'"');
                    i += 1;
                    break;
                } else {
                    let keep = if b[i] == b'\n' { b'\n' } else { b' ' };
                    both!(keep, keep);
                    i += 1;
                }
            }
            continue;
        }
        // Char literal vs lifetime.
        if c == b'\'' {
            let escaped = b.get(i + 1) == Some(&b'\\');
            let closed = b.get(i + 2) == Some(&b'\'');
            if escaped || closed {
                both!(b'\'', b'\'');
                i += 1;
                while i < b.len() {
                    if b[i] == b'\\' && i + 1 < b.len() {
                        both!(b' ', b' ');
                        both!(b' ', b' ');
                        i += 2;
                    } else if b[i] == b'\'' {
                        both!(b'\'', b'\'');
                        i += 1;
                        break;
                    } else {
                        both!(b' ', b' ');
                        i += 1;
                    }
                }
                continue;
            }
            // Lifetime: fall through, keep as-is.
        }
        both!(c, c);
        i += 1;
    }
    let code = String::from_utf8(code).expect("code view is ascii-transformed utf8");
    let com = String::from_utf8(com).expect("comment view is ascii-transformed utf8");
    (code, com)
}

/// Extract `audit:allow(lint, reason)` directives from the comment
/// view.  Only plain `//` comments count: doc comments are prose, and
/// anything inside a string literal was blanked before we got here.
fn parse_allows(comments: &str) -> Vec<Allow> {
    let mut out = Vec::new();
    for (ln, line) in comments.lines().enumerate() {
        let Some(p) = line.find("//") else { continue };
        let comment = &line[p..];
        if comment.starts_with("///") || comment.starts_with("//!") {
            continue;
        }
        let Some(start) = comment.find("audit:allow(") else { continue };
        let body = &comment[start + "audit:allow(".len()..];
        let Some(end) = body.find(')') else { continue };
        let inner = &body[..end];
        let (lint, reason) = match inner.find(',') {
            Some(c) => (inner[..c].trim(), inner[c + 1..].trim()),
            None => (inner.trim(), ""),
        };
        out.push(Allow {
            line: ln + 1,
            lint: lint.to_string(),
            reason: reason.to_string(),
            used: std::cell::Cell::new(false),
        });
    }
    out
}

/// Columns (0-based byte offsets) where `word` occurs as a whole
/// identifier in `line`.
pub fn ident_hits(line: &str, word: &str) -> Vec<usize> {
    let lb = line.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = line[from..].find(word) {
        let at = from + p;
        let pre_ok = at == 0 || !is_ident(lb[at - 1]);
        let end = at + word.len();
        let post_ok = end >= lb.len() || !is_ident(lb[end]);
        if pre_ok && post_ok {
            out.push(at);
        }
        from = at + word.len().max(1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_blanked_line_aligned() {
        let src = "let a = \"HashMap\"; // HashMap\nlet b = 1; /* multi\nline */ let c = 'x';\n";
        let v = code_view(src);
        assert_eq!(v.lines().count(), src.lines().count());
        assert!(!v.contains("HashMap"));
        assert!(v.contains("let a"));
        assert!(v.contains("let c"));
        assert!(!v.contains('x'), "char literal contents blanked");
    }

    #[test]
    fn raw_strings_and_lifetimes_survive() {
        let src = "fn f<'a>(s: &'a str) { let r = r#\"thread::spawn {\"#; }";
        let v = code_view(src);
        assert!(v.contains("<'a>"), "lifetime untouched");
        assert!(!v.contains("thread::spawn"));
        // Brace balance is preserved (the `{` inside the raw string is gone).
        let open = v.matches('{').count();
        let close = v.matches('}').count();
        assert_eq!((open, close), (1, 1));
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let v = code_view("a /* x /* y */ z */ b");
        assert!(v.contains('a') && v.contains('b'));
        assert!(!v.contains('y') && !v.contains('z'));
    }

    #[test]
    fn allow_directives_parse_with_and_without_reason() {
        let src = concat!(
            "x(); // audit:allow(det::unseeded-rng, seeded upstream)\n",
            "y(); // audit:allow(conc::lock-order)\n"
        );
        let f = SourceFile::parse("t.rs", src);
        assert_eq!(f.allows.len(), 2);
        assert_eq!(f.allows[0].lint, "det::unseeded-rng");
        assert_eq!(f.allows[0].reason, "seeded upstream");
        assert!(f.allows[1].reason.is_empty(), "missing reason detected");
        assert!(f.allowed("det::unseeded-rng", 1));
        assert!(f.allowed("det::unseeded-rng", 2), "covers the next line");
        assert!(!f.allowed("det::unseeded-rng", 3));
        assert!(!f.allowed("conc::lock-order", 2), "reasonless allow never suppresses");
    }

    #[test]
    fn directives_in_strings_and_docs_are_ignored() {
        let src = concat!(
            "/// audit:allow(det::unseeded-rng, doc prose)\n",
            "//! audit:allow(det::unseeded-rng, module prose)\n",
            "let s = \"// audit:allow(det::unseeded-rng, in a string)\";\n"
        );
        let f = SourceFile::parse("t.rs", src);
        assert!(f.allows.is_empty());
    }

    #[test]
    fn ident_hits_respects_word_boundaries() {
        assert_eq!(ident_hits("HashMap::new()", "HashMap"), vec![0]);
        assert!(ident_hits("MyHashMap::new()", "HashMap").is_empty());
        assert!(ident_hits("HashMapExt::new()", "HashMap").is_empty());
        assert_eq!(ident_hits("a HashMap b HashMap", "HashMap").len(), 2);
    }
}
