//! Configuration system: JSON experiment configs with presets.
//!
//! Proteo is "highly configurable" (§III); this module is the
//! file-facing half.  A config names a preset (`sarteco25` — the
//! paper's testbed and workload — or `tiny` for CI) and overrides any
//! subset of fields:
//!
//! ```json
//! {
//!   "preset": "sarteco25",
//!   "method": "rma-lockall",
//!   "strategy": "wd",
//!   "pairs": [[20, 160], [160, 20]],
//!   "reps": 5,
//!   "scale": 10,
//!   "win_pool": "on",
//!   "win_pool_cap": 8,
//!   "spawn_strategy": "async",
//!   "net": { "beta_register_gbps": 2.0, "eager_threshold": 65536 },
//!   "sam": { "flops_per_core": 2.0e9, "jitter": 0.02 }
//! }
//! ```
//!
//! The CLI (`proteo run --config file.json`) and the experiment
//! harnesses consume [`ExperimentConfig`].

use crate::mam::{Method, PlannerMode, SpawnStrategy, Strategy, WinPoolPolicy};
use crate::proteo::RunSpec;
use crate::simmpi::{FaultSpec, RmaSync};
use crate::sam::SamConfig;
use crate::util::json::Json;

/// A fully resolved experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub method: Method,
    pub strategy: Strategy,
    pub pairs: Vec<(usize, usize)>,
    pub reps: usize,
    pub scale: u64,
    pub seed: u64,
    /// Persistent RMA window pool (`"win_pool": "on"` / `true`, §VI).
    /// `"win_pool_cap": N` bounds the per-rank registration cache.
    pub win_pool: WinPoolPolicy,
    /// Spawn strategy of the Merge grow path
    /// (`"spawn_strategy": "sequential" | "parallel" | "async"`).
    pub spawn_strategy: SpawnStrategy,
    /// Chunked pipelined RMA registration (`"rma_chunk_kib": N`):
    /// segment size in KiB, 0 = off (seed unchunked path).
    pub rma_chunk_kib: u64,
    /// Pipelined deregistration (`"rma_dereg"`: bool or "on"/"off",
    /// default on): the teardown half of the chunked lifecycle
    /// pipeline.  Ignored when `rma_chunk_kib == 0`.
    pub rma_dereg: bool,
    /// `"planner": "auto" | "fixed"` — `auto` lets the cost-model
    /// planner override method/strategy/spawn/pool per resize.
    pub planner: PlannerMode,
    /// `"recalib"`: bool or "on"/"off" (default off) — online
    /// NetParams recalibration: the Auto planner consults a live
    /// estimate fed by observed resize spans and registration
    /// counters.  Off is bit-identical to the static planner.
    pub recalib: bool,
    /// `"rma_sync": "epoch" | "notify"` — RMA completion
    /// synchronization.  `epoch` (default) is the seed's passive
    /// epochs + collective teardown, bit for bit; `notify` completes
    /// on per-segment notification counters with local teardown.
    pub rma_sync: RmaSync,
    /// `"sched_cache"`: bool or "on"/"off" (default off) — persistent
    /// redistribution schedules, built once per
    /// `(from, to, structure, chunk)` and replayed for a validation
    /// handshake.  Off recomputes per resize (seed, bit for bit).
    pub sched_cache: bool,
    /// `"faults": "spawn=first1,mode=wave,..."` — deterministic fault
    /// injection (same `k=v,...` grammar as `--faults`).  Absent or
    /// inactive specs leave every run bit-identical to the healthy
    /// path.
    pub faults: Option<FaultSpec>,
    pub base: RunSpec,
}

impl ExperimentConfig {
    /// The paper's configuration (§V-A), one pair.
    pub fn sarteco25() -> ExperimentConfig {
        ExperimentConfig {
            method: Method::Collective,
            strategy: Strategy::Blocking,
            pairs: crate::proteo::sarteco25_pairs(),
            reps: 3,
            scale: 1,
            seed: 0xC0FFEE,
            win_pool: WinPoolPolicy::off(),
            spawn_strategy: SpawnStrategy::Sequential,
            rma_chunk_kib: 0,
            rma_dereg: true,
            planner: PlannerMode::Fixed,
            recalib: false,
            rma_sync: RmaSync::Epoch,
            sched_cache: false,
            faults: None,
            base: RunSpec::sarteco25(20, 160, Method::Collective, Strategy::Blocking),
        }
    }

    /// CI-sized configuration.
    pub fn tiny() -> ExperimentConfig {
        let mut c = ExperimentConfig::sarteco25();
        c.scale = 100;
        c.reps = 1;
        c.pairs = vec![(20, 160), (160, 20)];
        c
    }

    /// Materialize the run spec for one pair.
    pub fn spec_for(&self, ns: usize, nd: usize) -> RunSpec {
        let mut spec = self.base.clone();
        spec.ns = ns;
        spec.nd = nd;
        spec.method = self.method;
        spec.strategy = self.strategy;
        spec.seed = self.seed;
        spec.win_pool = self.win_pool;
        spec.spawn_strategy = self.spawn_strategy;
        spec.rma_chunk_kib = self.rma_chunk_kib;
        spec.rma_dereg = self.rma_dereg;
        spec.planner = self.planner;
        spec.recalib = self.recalib;
        spec.rma_sync = self.rma_sync;
        spec.sched_cache = self.sched_cache;
        spec.faults = self.faults.clone();
        if self.scale > 1 {
            spec.sam.matrix_elems /= self.scale;
            spec.sam.colind_elems /= self.scale;
            spec.sam.rowptr_elems = (spec.sam.rowptr_elems / self.scale).max(16);
            spec.sam.vector_elems = (spec.sam.vector_elems / self.scale).max(16);
            spec.sam.flops_per_iter /= self.scale as f64;
        }
        spec
    }

    /// Parse a JSON document, starting from the named preset.
    pub fn from_json(doc: &Json) -> Result<ExperimentConfig, String> {
        let preset = doc
            .get("preset")
            .and_then(|p| p.as_str())
            .unwrap_or("sarteco25");
        let mut cfg = match preset {
            "sarteco25" => ExperimentConfig::sarteco25(),
            "tiny" => ExperimentConfig::tiny(),
            other => return Err(format!("unknown preset '{other}'")),
        };
        if let Some(m) = doc.get("method").and_then(|v| v.as_str()) {
            cfg.method = Method::parse(m).ok_or_else(|| format!("bad method '{m}'"))?;
        }
        if let Some(s) = doc.get("strategy").and_then(|v| v.as_str()) {
            cfg.strategy = Strategy::parse(s).ok_or_else(|| format!("bad strategy '{s}'"))?;
        }
        if let Some(reps) = doc.get("reps").and_then(|v| v.as_usize()) {
            cfg.reps = reps.max(1);
        }
        if let Some(scale) = doc.get("scale").and_then(|v| v.as_u64()) {
            cfg.scale = scale.max(1);
        }
        if let Some(seed) = doc.get("seed").and_then(|v| v.as_u64()) {
            cfg.seed = seed;
        }
        if let Some(wp) = doc.get("win_pool") {
            cfg.win_pool = match (wp.as_bool(), wp.as_str()) {
                (Some(b), _) => {
                    if b {
                        WinPoolPolicy::on()
                    } else {
                        WinPoolPolicy::off()
                    }
                }
                (_, Some(s)) => {
                    WinPoolPolicy::parse(s).ok_or_else(|| format!("bad win_pool '{s}'"))?
                }
                _ => return Err("win_pool must be a bool or \"on\"/\"off\"".into()),
            };
        }
        if let Some(cap) = doc.get("win_pool_cap") {
            let cap = cap
                .as_usize()
                .ok_or("win_pool_cap must be a non-negative integer (0 = unbounded)")?;
            cfg.win_pool = cfg.win_pool.with_cap(cap);
        }
        if let Some(ss) = doc.get("spawn_strategy") {
            let ss = ss.as_str().ok_or("spawn_strategy must be a string")?;
            cfg.spawn_strategy = SpawnStrategy::parse(ss).ok_or_else(|| {
                format!("bad spawn_strategy '{ss}' (sequential | parallel | async)")
            })?;
        }
        if let Some(ck) = doc.get("rma_chunk_kib") {
            cfg.rma_chunk_kib = ck
                .as_u64()
                .ok_or("rma_chunk_kib must be a non-negative integer (KiB; 0 = off)")?;
        }
        if let Some(rd) = doc.get("rma_dereg") {
            cfg.rma_dereg = match (rd.as_bool(), rd.as_str()) {
                (Some(b), _) => b,
                (_, Some(s)) => crate::util::cli::parse_toggle(s)
                    .ok_or_else(|| format!("bad rma_dereg '{s}' (on | off)"))?,
                _ => return Err("rma_dereg must be a bool or \"on\"/\"off\"".into()),
            };
        }
        if let Some(pl) = doc.get("planner") {
            let pl = pl.as_str().ok_or("planner must be a string")?;
            cfg.planner = PlannerMode::parse(pl)
                .ok_or_else(|| format!("bad planner '{pl}' (fixed | auto)"))?;
        }
        if let Some(rc) = doc.get("recalib") {
            cfg.recalib = match (rc.as_bool(), rc.as_str()) {
                (Some(b), _) => b,
                (_, Some(s)) => crate::util::cli::parse_toggle(s)
                    .ok_or_else(|| format!("bad recalib '{s}' (on | off)"))?,
                _ => return Err("recalib must be a bool or \"on\"/\"off\"".into()),
            };
        }
        if let Some(rs) = doc.get("rma_sync") {
            let rs = rs.as_str().ok_or("rma_sync must be a string")?;
            cfg.rma_sync = RmaSync::parse(rs)
                .ok_or_else(|| format!("bad rma_sync '{rs}' (epoch | notify)"))?;
        }
        if let Some(sc) = doc.get("sched_cache") {
            cfg.sched_cache = match (sc.as_bool(), sc.as_str()) {
                (Some(b), _) => b,
                (_, Some(s)) => crate::util::cli::parse_toggle(s)
                    .ok_or_else(|| format!("bad sched_cache '{s}' (on | off)"))?,
                _ => return Err("sched_cache must be a bool or \"on\"/\"off\"".into()),
            };
        }
        if let Some(f) = doc.get("faults") {
            let f = f.as_str().ok_or("faults must be a spec string (k=v,...)")?;
            cfg.faults = if f.is_empty() {
                None
            } else {
                Some(FaultSpec::parse(f).map_err(|e| format!("bad faults: {e}"))?)
            };
        }
        if let Some(pairs) = doc.get("pairs").and_then(|v| v.as_arr()) {
            cfg.pairs = pairs
                .iter()
                .map(|p| {
                    let arr = p.as_arr().ok_or("pair must be [ns, nd]")?;
                    if arr.len() != 2 {
                        return Err("pair must have 2 entries".to_string());
                    }
                    let ns = arr[0].as_usize().ok_or("ns must be integer")?;
                    let nd = arr[1].as_usize().ok_or("nd must be integer")?;
                    if ns == 0 || nd == 0 || ns == nd {
                        return Err(format!("invalid pair ({ns}, {nd})"));
                    }
                    Ok((ns, nd))
                })
                .collect::<Result<Vec<_>, String>>()?;
        }
        if let Some(net) = doc.get("net") {
            apply_net_overrides(&mut cfg.base, net)?;
        }
        if let Some(sam) = doc.get("sam") {
            apply_sam_overrides(&mut cfg.base.sam, sam)?;
        }
        if let Some(w) = doc.get("warmup_iters").and_then(|v| v.as_u64()) {
            cfg.base.warmup_iters = w;
        }
        if let Some(p) = doc.get("post_iters").and_then(|v| v.as_u64()) {
            cfg.base.post_iters = p;
        }
        Ok(cfg)
    }

    /// Parse from JSON source text.
    pub fn from_str(src: &str) -> Result<ExperimentConfig, String> {
        let doc = Json::parse(src).map_err(|e| e.to_string())?;
        ExperimentConfig::from_json(&doc)
    }

    /// Load from a file.
    pub fn from_file(path: &str) -> Result<ExperimentConfig, String> {
        let src = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        ExperimentConfig::from_str(&src)
    }

    /// Serialize the resolved configuration (reports, provenance).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::str(self.method.label())),
            (
                "strategy",
                Json::str(format!("{:?}", self.strategy).to_lowercase()),
            ),
            (
                "pairs",
                Json::Arr(
                    self.pairs
                        .iter()
                        .map(|&(a, b)| {
                            Json::Arr(vec![Json::num(a as f64), Json::num(b as f64)])
                        })
                        .collect(),
                ),
            ),
            ("reps", Json::num(self.reps as f64)),
            ("scale", Json::num(self.scale as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("win_pool", Json::str(self.win_pool.label())),
            ("win_pool_cap", Json::num(self.win_pool.cap as f64)),
            ("spawn_strategy", Json::str(self.spawn_strategy.label())),
            ("rma_chunk_kib", Json::num(self.rma_chunk_kib as f64)),
            ("rma_dereg", Json::Bool(self.rma_dereg)),
            ("planner", Json::str(self.planner.label())),
            ("recalib", Json::Bool(self.recalib)),
            ("rma_sync", Json::str(self.rma_sync.label())),
            ("sched_cache", Json::Bool(self.sched_cache)),
            (
                "faults",
                self.faults
                    .as_ref()
                    .map_or(Json::Null, |f| Json::str(f.to_spec_string())),
            ),
            ("total_bytes", Json::num(self.base.sam.total_bytes() as f64)),
        ])
    }
}

fn apply_net_overrides(spec: &mut RunSpec, net: &Json) -> Result<(), String> {
    let p = &mut spec.net;
    if let Some(v) = net.get("beta_register_gbps").and_then(|v| v.as_f64()) {
        if v <= 0.0 {
            return Err("beta_register_gbps must be > 0".into());
        }
        p.beta_register = 1.0 / (v * 1e9);
    }
    if let Some(v) = net.get("inter_gbps").and_then(|v| v.as_f64()) {
        if v <= 0.0 {
            return Err("inter_gbps must be > 0".into());
        }
        p.beta_inter = 1.0 / (v * 1e9);
    }
    if let Some(v) = net.get("eager_threshold").and_then(|v| v.as_u64()) {
        p.eager_threshold = v;
    }
    if let Some(v) = net.get("progress_chunk").and_then(|v| v.as_u64()) {
        p.progress_chunk = v.max(1);
    }
    if let Some(v) = net.get("oversub_factor").and_then(|v| v.as_f64()) {
        p.oversub_factor = v;
    }
    if let Some(v) = net.get("small_lane_max_wait").and_then(|v| v.as_f64()) {
        p.small_lane_max_wait = v;
    }
    if let Some(v) = net.get("spawn_cost").and_then(|v| v.as_f64()) {
        spec.spawn_cost = v;
    }
    Ok(())
}

fn apply_sam_overrides(sam: &mut SamConfig, j: &Json) -> Result<(), String> {
    if let Some(v) = j.get("flops_per_core").and_then(|v| v.as_f64()) {
        if v <= 0.0 {
            return Err("flops_per_core must be > 0".into());
        }
        sam.flops_per_core = v;
    }
    if let Some(v) = j.get("flops_per_iter").and_then(|v| v.as_f64()) {
        sam.flops_per_iter = v;
    }
    if let Some(v) = j.get("jitter").and_then(|v| v.as_f64()) {
        sam.jitter = v.clamp(0.0, 0.9);
    }
    if let Some(v) = j.get("matrix_elems").and_then(|v| v.as_u64()) {
        sam.matrix_elems = v;
    }
    if let Some(v) = j.get("vector_elems").and_then(|v| v.as_u64()) {
        sam.vector_elems = v;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_preset_parses() {
        let cfg = ExperimentConfig::from_str(r#"{}"#).unwrap();
        assert_eq!(cfg.pairs.len(), 12);
        assert_eq!(cfg.method, Method::Collective);
    }

    #[test]
    fn full_override_parses() {
        let cfg = ExperimentConfig::from_str(
            r#"{
                "preset": "tiny",
                "method": "rma-lockall",
                "strategy": "wd",
                "pairs": [[20, 160], [80, 40]],
                "reps": 7,
                "scale": 50,
                "seed": 99,
                "net": { "beta_register_gbps": 2.0, "inter_gbps": 5.0 },
                "sam": { "jitter": 0.05 }
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.method, Method::RmaLockall);
        assert_eq!(cfg.strategy, Strategy::WaitDrains);
        assert_eq!(cfg.pairs, vec![(20, 160), (80, 40)]);
        assert_eq!(cfg.reps, 7);
        assert_eq!(cfg.seed, 99);
        assert!((cfg.base.net.beta_register - 0.5e-9).abs() < 1e-15);
        assert!((cfg.base.net.beta_inter - 0.2e-9).abs() < 1e-15);
        assert!((cfg.base.sam.jitter - 0.05).abs() < 1e-12);
    }

    #[test]
    fn spec_for_applies_scale() {
        let mut cfg = ExperimentConfig::sarteco25();
        cfg.scale = 100;
        let spec = cfg.spec_for(20, 40);
        assert_eq!(spec.ns, 20);
        assert_eq!(spec.nd, 40);
        assert_eq!(spec.sam.matrix_elems, SamConfig::sarteco25().matrix_elems / 100);
    }

    #[test]
    fn spec_for_mam_cfg_propagates_every_knob() {
        // A config with every reconfiguration knob off its default must
        // reach the MaM layer intact through `spec_for` + the
        // `ReconfigCfg` builder (`RunSpec::mam_cfg`).
        let cfg = ExperimentConfig::from_str(
            r#"{
                "method": "rma-lockall", "strategy": "wd",
                "spawn_strategy": "async",
                "win_pool": "on", "win_pool_cap": 2,
                "rma_chunk_kib": 256, "rma_dereg": false,
                "planner": "auto", "recalib": true,
                "rma_sync": "notify", "sched_cache": true
            }"#,
        )
        .unwrap();
        let spec = cfg.spec_for(20, 40);
        let mam = spec.mam_cfg();
        assert_eq!(mam.method, Method::RmaLockall);
        assert_eq!(mam.strategy, Strategy::WaitDrains);
        assert_eq!(mam.spawn_strategy, SpawnStrategy::Async);
        assert_eq!(mam.spawn_cost.to_bits(), spec.spawn_cost.to_bits());
        assert!(mam.win_pool.enabled);
        assert_eq!(mam.win_pool.cap, 2);
        assert_eq!(mam.rma_chunk_kib, 256);
        assert!(!mam.rma_dereg);
        assert_eq!(mam.planner, PlannerMode::Auto);
        assert!(mam.recalib);
        assert_eq!(mam.rma_sync, RmaSync::Notify);
        assert!(mam.sched_cache);
        // And the default config builds the default MaM cfg.
        let def = ExperimentConfig::from_str("{}").unwrap().spec_for(4, 2).mam_cfg();
        let base = crate::mam::ReconfigCfg::default();
        assert_eq!(def.spawn_strategy, base.spawn_strategy);
        assert_eq!(def.win_pool, base.win_pool);
        assert_eq!(def.rma_chunk_kib, base.rma_chunk_kib);
        assert_eq!(def.rma_dereg, base.rma_dereg);
        assert_eq!(def.recalib, base.recalib);
        assert_eq!(def.rma_sync, base.rma_sync);
        assert_eq!(def.sched_cache, base.sched_cache);
    }

    #[test]
    fn rma_sync_parses_propagates_and_rejects_bad_values() {
        // Default: epoch (the seed's passive-epoch path, bit for bit).
        let cfg = ExperimentConfig::from_str(r#"{}"#).unwrap();
        assert_eq!(cfg.rma_sync, RmaSync::Epoch);
        assert_eq!(cfg.spec_for(20, 40).rma_sync, RmaSync::Epoch);
        // All spellings the CLI accepts.
        for (src, want) in [
            (r#"{"rma_sync": "epoch"}"#, RmaSync::Epoch),
            (r#"{"rma_sync": "epochs"}"#, RmaSync::Epoch),
            (r#"{"rma_sync": "notify"}"#, RmaSync::Notify),
            (r#"{"rma_sync": "notified"}"#, RmaSync::Notify),
            (r#"{"rma_sync": "NOTIFY"}"#, RmaSync::Notify),
        ] {
            let cfg = ExperimentConfig::from_str(src).unwrap();
            assert_eq!(cfg.rma_sync, want, "{src}");
            // Round-trip into the per-pair run spec and the MaM cfg.
            assert_eq!(cfg.spec_for(20, 160).rma_sync, want, "{src}");
            assert_eq!(cfg.spec_for(20, 160).mam_cfg().rma_sync, want, "{src}");
        }
        // Bad values error out with the grammar in the message.
        let err = ExperimentConfig::from_str(r#"{"rma_sync": "psychic"}"#).unwrap_err();
        assert!(err.contains("rma_sync"), "{err}");
        assert!(ExperimentConfig::from_str(r#"{"rma_sync": 2}"#).is_err());
        // Provenance carries the mode back out.
        let cfg = ExperimentConfig::from_str(r#"{"rma_sync": "notify"}"#).unwrap();
        assert_eq!(cfg.to_json().get_path("rma_sync").unwrap().as_str(), Some("notify"));
    }

    #[test]
    fn sched_cache_parses_propagates_and_rejects_bad_values() {
        // Default: off (per-resize recompute, the seed path).
        let cfg = ExperimentConfig::from_str(r#"{}"#).unwrap();
        assert!(!cfg.sched_cache);
        assert!(!cfg.spec_for(20, 40).sched_cache);
        // Bool and toggle-string spellings.
        for (src, want) in [
            (r#"{"sched_cache": true}"#, true),
            (r#"{"sched_cache": false}"#, false),
            (r#"{"sched_cache": "on"}"#, true),
            (r#"{"sched_cache": "off"}"#, false),
        ] {
            let cfg = ExperimentConfig::from_str(src).unwrap();
            assert_eq!(cfg.sched_cache, want, "{src}");
            assert_eq!(cfg.spec_for(20, 160).sched_cache, want, "{src}");
            assert_eq!(cfg.spec_for(20, 160).mam_cfg().sched_cache, want, "{src}");
        }
        let err = ExperimentConfig::from_str(r#"{"sched_cache": "sideways"}"#).unwrap_err();
        assert!(err.contains("sched_cache"), "{err}");
        assert!(ExperimentConfig::from_str(r#"{"sched_cache": 3}"#).is_err());
        // Provenance carries the flag back out.
        let cfg = ExperimentConfig::from_str(r#"{"sched_cache": "on"}"#).unwrap();
        assert_eq!(cfg.to_json().get_path("sched_cache").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn win_pool_toggle_parses_and_propagates() {
        // Default: off (the paper's cold path).
        let cfg = ExperimentConfig::from_str(r#"{}"#).unwrap();
        assert_eq!(cfg.win_pool, WinPoolPolicy::off());
        assert!(!cfg.spec_for(20, 40).win_pool.enabled);
        // String and bool spellings.
        for src in [r#"{"win_pool": "on"}"#, r#"{"win_pool": true}"#] {
            let cfg = ExperimentConfig::from_str(src).unwrap();
            assert_eq!(cfg.win_pool, WinPoolPolicy::on(), "{src}");
            assert!(cfg.spec_for(20, 40).win_pool.enabled);
        }
        let cfg = ExperimentConfig::from_str(r#"{"win_pool": "off"}"#).unwrap();
        assert_eq!(cfg.win_pool, WinPoolPolicy::off());
        assert!(ExperimentConfig::from_str(r#"{"win_pool": "sideways"}"#).is_err());
        assert!(ExperimentConfig::from_str(r#"{"win_pool": 3}"#).is_err());
        // Provenance includes the toggle.
        let cfg = ExperimentConfig::from_str(r#"{"win_pool": "on"}"#).unwrap();
        assert_eq!(
            cfg.to_json().get_path("win_pool").unwrap().as_str(),
            Some("on")
        );
    }

    #[test]
    fn spawn_strategy_parses_propagates_and_rejects_bad_values() {
        // Default: sequential (the paper's single-constant model).
        let cfg = ExperimentConfig::from_str(r#"{}"#).unwrap();
        assert_eq!(cfg.spawn_strategy, SpawnStrategy::Sequential);
        assert_eq!(cfg.spec_for(20, 40).spawn_strategy, SpawnStrategy::Sequential);
        // All spellings the CLI accepts.
        for (src, want) in [
            (r#"{"spawn_strategy": "sequential"}"#, SpawnStrategy::Sequential),
            (r#"{"spawn_strategy": "seq"}"#, SpawnStrategy::Sequential),
            (r#"{"spawn_strategy": "parallel"}"#, SpawnStrategy::Parallel),
            (r#"{"spawn_strategy": "par"}"#, SpawnStrategy::Parallel),
            (r#"{"spawn_strategy": "async"}"#, SpawnStrategy::Async),
            (r#"{"spawn_strategy": "ASYNC"}"#, SpawnStrategy::Async),
        ] {
            let cfg = ExperimentConfig::from_str(src).unwrap();
            assert_eq!(cfg.spawn_strategy, want, "{src}");
            // Round-trip into the per-pair run spec.
            assert_eq!(cfg.spec_for(20, 40).spawn_strategy, want, "{src}");
        }
        // Bad values error out with the grammar in the message.
        let err = ExperimentConfig::from_str(r#"{"spawn_strategy": "forkbomb"}"#).unwrap_err();
        assert!(err.contains("spawn_strategy"), "{err}");
        assert!(ExperimentConfig::from_str(r#"{"spawn_strategy": 3}"#).is_err());
        // Provenance carries the label back out.
        let cfg = ExperimentConfig::from_str(r#"{"spawn_strategy": "parallel"}"#).unwrap();
        assert_eq!(
            cfg.to_json().get_path("spawn_strategy").unwrap().as_str(),
            Some("parallel")
        );
    }

    #[test]
    fn win_pool_cap_parses_propagates_and_rejects_bad_values() {
        // Default: unbounded.
        let cfg = ExperimentConfig::from_str(r#"{"win_pool": "on"}"#).unwrap();
        assert_eq!(cfg.win_pool.cap, 0);
        // Cap composes with the toggle regardless of key order.
        let cfg =
            ExperimentConfig::from_str(r#"{"win_pool": "on", "win_pool_cap": 8}"#).unwrap();
        assert!(cfg.win_pool.enabled);
        assert_eq!(cfg.win_pool.cap, 8);
        assert_eq!(cfg.spec_for(20, 40).win_pool.cap, 8);
        // Bad values error out.
        assert!(ExperimentConfig::from_str(r#"{"win_pool_cap": -1}"#).is_err());
        assert!(ExperimentConfig::from_str(r#"{"win_pool_cap": 1.5}"#).is_err());
        assert!(ExperimentConfig::from_str(r#"{"win_pool_cap": "many"}"#).is_err());
        // Provenance includes the cap.
        assert_eq!(
            cfg.to_json().get_path("win_pool_cap").unwrap().as_usize(),
            Some(8)
        );
    }

    #[test]
    fn rma_chunk_parses_propagates_and_rejects_bad_values() {
        // Default: off (the seed unchunked path).
        let cfg = ExperimentConfig::from_str(r#"{}"#).unwrap();
        assert_eq!(cfg.rma_chunk_kib, 0);
        assert_eq!(cfg.spec_for(20, 40).rma_chunk_kib, 0);
        // Round-trip into the per-pair run spec.
        let cfg = ExperimentConfig::from_str(r#"{"rma_chunk_kib": 1024}"#).unwrap();
        assert_eq!(cfg.rma_chunk_kib, 1024);
        assert_eq!(cfg.spec_for(20, 160).rma_chunk_kib, 1024);
        // Explicit zero is the seed path.
        let cfg = ExperimentConfig::from_str(r#"{"rma_chunk_kib": 0}"#).unwrap();
        assert_eq!(cfg.rma_chunk_kib, 0);
        // Bad values error out with the grammar in the message.
        let err = ExperimentConfig::from_str(r#"{"rma_chunk_kib": -4}"#).unwrap_err();
        assert!(err.contains("rma_chunk_kib"), "{err}");
        assert!(ExperimentConfig::from_str(r#"{"rma_chunk_kib": 1.5}"#).is_err());
        assert!(ExperimentConfig::from_str(r#"{"rma_chunk_kib": "big"}"#).is_err());
        // Provenance carries the chunk size back out.
        let cfg = ExperimentConfig::from_str(r#"{"rma_chunk_kib": 256}"#).unwrap();
        assert_eq!(
            cfg.to_json().get_path("rma_chunk_kib").unwrap().as_u64(),
            Some(256)
        );
    }

    #[test]
    fn rma_dereg_parses_propagates_and_rejects_bad_values() {
        // Default: on (the full lifecycle pipeline when chunked).
        let cfg = ExperimentConfig::from_str(r#"{}"#).unwrap();
        assert!(cfg.rma_dereg);
        assert!(cfg.spec_for(20, 40).rma_dereg);
        // Bool and toggle-string spellings.
        for (src, want) in [
            (r#"{"rma_dereg": false}"#, false),
            (r#"{"rma_dereg": true}"#, true),
            (r#"{"rma_dereg": "off"}"#, false),
            (r#"{"rma_dereg": "on"}"#, true),
        ] {
            let cfg = ExperimentConfig::from_str(src).unwrap();
            assert_eq!(cfg.rma_dereg, want, "{src}");
            assert_eq!(cfg.spec_for(20, 160).rma_dereg, want, "{src}");
        }
        let err = ExperimentConfig::from_str(r#"{"rma_dereg": "sideways"}"#).unwrap_err();
        assert!(err.contains("rma_dereg"), "{err}");
        assert!(ExperimentConfig::from_str(r#"{"rma_dereg": 3}"#).is_err());
        // Provenance carries the flag back out.
        let cfg = ExperimentConfig::from_str(r#"{"rma_dereg": "off"}"#).unwrap();
        assert_eq!(cfg.to_json().get_path("rma_dereg").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn recalib_parses_propagates_and_rejects_bad_values() {
        // Default: off (bit-identical static planner path).
        let cfg = ExperimentConfig::from_str(r#"{}"#).unwrap();
        assert!(!cfg.recalib);
        assert!(!cfg.spec_for(20, 40).recalib);
        // Bool and toggle-string spellings.
        for (src, want) in [
            (r#"{"recalib": true}"#, true),
            (r#"{"recalib": false}"#, false),
            (r#"{"recalib": "on"}"#, true),
            (r#"{"recalib": "off"}"#, false),
        ] {
            let cfg = ExperimentConfig::from_str(src).unwrap();
            assert_eq!(cfg.recalib, want, "{src}");
            assert_eq!(cfg.spec_for(20, 160).recalib, want, "{src}");
        }
        let err = ExperimentConfig::from_str(r#"{"recalib": "sideways"}"#).unwrap_err();
        assert!(err.contains("recalib"), "{err}");
        assert!(ExperimentConfig::from_str(r#"{"recalib": 3}"#).is_err());
        // Provenance carries the flag back out.
        let cfg = ExperimentConfig::from_str(r#"{"recalib": "on"}"#).unwrap();
        assert_eq!(cfg.to_json().get_path("recalib").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn planner_parses_propagates_and_rejects_bad_values() {
        // Default: fixed (seed behaviour).
        let cfg = ExperimentConfig::from_str(r#"{}"#).unwrap();
        assert_eq!(cfg.planner, PlannerMode::Fixed);
        assert_eq!(cfg.spec_for(20, 40).planner, PlannerMode::Fixed);
        for (src, want) in [
            (r#"{"planner": "fixed"}"#, PlannerMode::Fixed),
            (r#"{"planner": "auto"}"#, PlannerMode::Auto),
            (r#"{"planner": "AUTO"}"#, PlannerMode::Auto),
        ] {
            let cfg = ExperimentConfig::from_str(src).unwrap();
            assert_eq!(cfg.planner, want, "{src}");
            assert_eq!(cfg.spec_for(20, 40).planner, want, "{src}");
        }
        let err = ExperimentConfig::from_str(r#"{"planner": "oracle"}"#).unwrap_err();
        assert!(err.contains("planner"), "{err}");
        assert!(ExperimentConfig::from_str(r#"{"planner": 1}"#).is_err());
        // Provenance carries the mode back out.
        let cfg = ExperimentConfig::from_str(r#"{"planner": "auto"}"#).unwrap();
        assert_eq!(cfg.to_json().get_path("planner").unwrap().as_str(), Some("auto"));
    }

    #[test]
    fn faults_parse_propagate_and_reject_bad_values() {
        // Default: no injection (the healthy path, bit for bit).
        let cfg = ExperimentConfig::from_str(r#"{}"#).unwrap();
        assert!(cfg.faults.is_none());
        assert!(cfg.spec_for(20, 40).faults.is_none());
        // Empty string is an explicit off.
        let cfg = ExperimentConfig::from_str(r#"{"faults": ""}"#).unwrap();
        assert!(cfg.faults.is_none());
        // A spec string round-trips into the per-pair run spec.
        let cfg = ExperimentConfig::from_str(
            r#"{"faults": "spawn=first2,mode=wave,retries=3,seed=7"}"#,
        )
        .unwrap();
        let f = cfg.faults.clone().unwrap();
        assert_eq!(f.spawn_fail_first, 2);
        assert_eq!(f.retries, 3);
        assert_eq!(f.seed, 7);
        assert_eq!(
            cfg.spec_for(20, 160).faults.unwrap().to_spec_string(),
            f.to_spec_string()
        );
        // Bad values error out with the grammar in the message.
        let err = ExperimentConfig::from_str(r#"{"faults": "spawn=backwards"}"#).unwrap_err();
        assert!(err.contains("faults"), "{err}");
        assert!(ExperimentConfig::from_str(r#"{"faults": 3}"#).is_err());
        // Provenance carries the canonical spec string back out (and
        // null when off).
        assert_eq!(
            cfg.to_json().get_path("faults").unwrap().as_str().map(String::from),
            Some(f.to_spec_string())
        );
        let off = ExperimentConfig::from_str(r#"{}"#).unwrap();
        assert!(matches!(off.to_json().get_path("faults"), Some(&Json::Null)));
    }

    #[test]
    fn bad_inputs_are_rejected() {
        assert!(ExperimentConfig::from_str(r#"{"preset": "nope"}"#).is_err());
        assert!(ExperimentConfig::from_str(r#"{"method": "smoke"}"#).is_err());
        assert!(ExperimentConfig::from_str(r#"{"pairs": [[20, 20]]}"#).is_err());
        assert!(ExperimentConfig::from_str(r#"{"pairs": [[20]]}"#).is_err());
        assert!(
            ExperimentConfig::from_str(r#"{"net": {"inter_gbps": -1}}"#).is_err()
        );
        assert!(ExperimentConfig::from_str("not json").is_err());
    }

    #[test]
    fn to_json_roundtrips_provenance() {
        let cfg = ExperimentConfig::tiny();
        let j = cfg.to_json();
        assert_eq!(j.get_path("reps").unwrap().as_usize(), Some(1));
        assert!(j.get_path("pairs").unwrap().as_arr().unwrap().len() == 2);
    }
}
