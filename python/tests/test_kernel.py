"""L1 correctness: the Pallas block-ELL SpMV against the pure-jnp
oracle (and a dense ground truth), swept over shapes with hypothesis.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.spmv_ell import mxu_flops_per_step, spmv_block_ell, vmem_bytes


def random_ell(rng, nbr, k, br, bc, nbc):
    data = rng.standard_normal((nbr, k, br, bc)).astype(np.float32)
    idx = rng.integers(0, nbc, size=(nbr, k)).astype(np.int32)
    x = rng.standard_normal((nbc * bc,)).astype(np.float32)
    return jnp.asarray(data), jnp.asarray(idx), jnp.asarray(x)


def test_kernel_matches_ref_basic():
    rng = np.random.default_rng(0)
    data, idx, x = random_ell(rng, nbr=8, k=3, br=16, bc=16, nbc=8)
    y = spmv_block_ell(data, idx, x)
    y_ref = ref.spmv_ref(data, idx, x)
    assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5, atol=1e-5)


def test_kernel_matches_dense():
    rng = np.random.default_rng(1)
    data, idx, x = random_ell(rng, nbr=4, k=2, br=8, bc=8, nbc=4)
    y = spmv_block_ell(data, idx, x)
    dense = ref.ell_to_dense(data, idx, x.shape[0])
    assert_allclose(np.asarray(y), dense @ np.asarray(x), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    nbr=st.integers(1, 6),
    k=st.integers(1, 4),
    br=st.sampled_from([4, 8, 16]),
    bc=st.sampled_from([4, 8, 16]),
    nbc=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_swept(nbr, k, br, bc, nbc, seed):
    rng = np.random.default_rng(seed)
    data, idx, x = random_ell(rng, nbr, k, br, bc, nbc)
    y = spmv_block_ell(data, idx, x)
    y_ref = ref.spmv_ref(data, idx, x)
    assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


def test_duplicate_block_columns_accumulate():
    # Two blocks pointing at the same column must both contribute.
    rng = np.random.default_rng(2)
    data = rng.standard_normal((1, 2, 4, 4)).astype(np.float32)
    idx = np.zeros((1, 2), dtype=np.int32)
    x = rng.standard_normal((4,)).astype(np.float32)
    y = spmv_block_ell(jnp.asarray(data), jnp.asarray(idx), jnp.asarray(x))
    want = (data[0, 0] + data[0, 1]) @ x
    assert_allclose(np.asarray(y), want, rtol=1e-5, atol=1e-5)


def test_zero_padding_blocks_are_neutral():
    rng = np.random.default_rng(3)
    data, idx, x = random_ell(rng, nbr=3, k=2, br=8, bc=8, nbc=3)
    # Append an all-zero block slot with an arbitrary index.
    data2 = jnp.concatenate([data, jnp.zeros((3, 1, 8, 8), jnp.float32)], axis=1)
    idx2 = jnp.concatenate([idx, jnp.ones((3, 1), jnp.int32)], axis=1)
    y1 = spmv_block_ell(data, idx, x)
    y2 = spmv_block_ell(data2, idx2, x)
    assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6, atol=1e-6)


def test_laplacian_ell_matches_dense_stencil():
    data, idx = ref.laplacian_2d_block_ell(8)
    n = 64
    dense = ref.ell_to_dense(data, idx, n)
    # Dense must be symmetric with 4 on the diagonal.
    assert_allclose(dense, dense.T)
    assert_allclose(np.diag(dense), 4.0 * np.ones(n))
    # Row sums: 0 for interior, positive at the boundary.
    assert dense.sum() > 0


def test_kernel_under_jit_and_vjp_free_path():
    # The lowered artifact wraps the kernel in jit: check jit parity.
    rng = np.random.default_rng(4)
    data, idx, x = random_ell(rng, nbr=4, k=3, br=8, bc=8, nbc=4)
    y_eager = spmv_block_ell(data, idx, x)
    y_jit = jax.jit(spmv_block_ell)(data, idx, x)
    assert_allclose(np.asarray(y_eager), np.asarray(y_jit), rtol=1e-6, atol=1e-6)


def test_perf_model_fits_vmem():
    # Structure check promised in DESIGN.md §Perf: the default artifact
    # must fit VMEM with big margin, and MXU work must be nonzero.
    assert vmem_bytes(64, 3, 64, 64, 4096) < 16 * 1024 * 1024 // 8
    assert mxu_flops_per_step(3, 64, 64, rows_per_step=16) == 2 * 16 * 3 * 64 * 64
