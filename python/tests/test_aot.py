"""AOT bridge checks: lowering produces loadable HLO text whose
numerics match the eager model, and the manifest describes the
artifact truthfully.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import aot, model
from compile.kernels import ref


def test_build_produces_text_and_manifest():
    cg_text, spmv_text, manifest = aot.build(grid=8)
    assert cg_text.startswith("HloModule")
    assert spmv_text.startswith("HloModule")
    assert "custom-call" not in cg_text.lower(), "Mosaic call leaked: not CPU-loadable"
    assert manifest["n"] == 64
    assert manifest["entries"]["cg_step"]["file"] == "cg_step.hlo.txt"
    assert manifest["perf_model"]["grid_steps"] == manifest["nbr"] // min(manifest["nbr"], 16)
    json.dumps(manifest)  # serializable


def test_lowered_computation_executes_like_eager():
    """Execute the lowered computation through the raw XLA client (the
    same lowering whose `as_hlo_text()` becomes the artifact) and
    compare with the eager model.  Loading the *text* is exercised on
    the Rust side (`rust/tests/integration_runtime.rs`), which is the
    real consumer.
    """
    grid = 8
    lowered = jax.jit(model.cg_step).lower(
        *model.shapes(grid, 3, grid, grid, grid * grid)
    )
    client = jax.devices("cpu")[0].client
    ir = str(lowered.compiler_ir("stablehlo"))
    try:
        # jax >= 0.6: compile_and_load wants an explicit device list.
        from jax._src.lib import _jax

        exe = client.compile_and_load(ir, _jax.DeviceList(tuple(jax.devices("cpu"))))
    except (ImportError, AttributeError):
        # jax 0.4/0.5: Client.compile takes the MLIR module directly.
        # (AttributeError covers mid-migration versions where the _jax
        # module exists but compile_and_load does not.)
        exe = client.compile(ir)
    data, idx = ref.laplacian_2d_block_ell(grid)
    b = np.random.default_rng(0).standard_normal((grid * grid,)).astype(np.float32)
    state = model.cg_state_init(jnp.asarray(data), jnp.asarray(idx), jnp.asarray(b))
    args = [np.asarray(data), np.asarray(idx)] + [np.asarray(s) for s in state]
    outs = exe.execute([client.buffer_from_pyval(a) for a in args])
    want = model.cg_step(jnp.asarray(data), jnp.asarray(idx), *state)
    for g, w in zip(outs, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=2e-4, atol=2e-4)
