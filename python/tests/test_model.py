"""L2 correctness: the CG step (kernel inside) against the jnp oracle,
and full CG convergence on the Laplacian test problem.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref


def laplacian_system(grid, seed=0):
    data, idx = ref.laplacian_2d_block_ell(grid)
    n = grid * grid
    rng = np.random.default_rng(seed)
    b = rng.standard_normal((n,)).astype(np.float32)
    return jnp.asarray(data), jnp.asarray(idx), jnp.asarray(b)


def test_cg_step_matches_ref():
    data, idx, b = laplacian_system(8)
    state = model.cg_state_init(data, idx, b)
    out_model = model.cg_step(data, idx, *state)
    out_ref = ref.cg_step_ref(data, idx, *state)
    for a, c in zip(out_model, out_ref):
        assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(grid=st.sampled_from([4, 8, 16]), steps=st.integers(1, 5), seed=st.integers(0, 999))
def test_cg_step_chain_matches_ref(grid, steps, seed):
    data, idx, b = laplacian_system(grid, seed)
    sm = model.cg_state_init(data, idx, b)
    sr = sm
    for _ in range(steps):
        sm = model.cg_step(data, idx, *sm)
        sr = ref.cg_step_ref(data, idx, *sr)
    # rr (last element) is the tightest scalar summary.
    assert_allclose(float(sm[3]), float(sr[3]), rtol=5e-3)


def test_cg_converges_on_laplacian():
    # CG on the 64-dof Laplacian converges in well under 40 iterations;
    # do NOT iterate past full convergence — rr underflows to 0 in f32
    # and beta = 0/0 turns NaN (plain CG has no breakdown guard).
    data, idx, b = laplacian_system(8)
    state = model.cg_state_init(data, idx, b)
    rr0 = float(state[3])
    for _ in range(40):
        state = model.cg_step(data, idx, *state)
    assert float(state[3]) < 1e-6 * rr0, f"no convergence: {float(state[3])}"
    # And the solution actually solves the system.
    x = state[0]
    res = ref.spmv_ref(data, idx, x) - b
    assert float(jnp.dot(res, res)) < 1e-5 * rr0


def test_state_init():
    data, idx, b = laplacian_system(4)
    x, r, p, rr = model.cg_state_init(data, idx, b)
    assert_allclose(np.asarray(x), 0.0)
    assert_allclose(np.asarray(r), np.asarray(b))
    assert_allclose(np.asarray(p), np.asarray(b))
    assert_allclose(float(rr), float(jnp.dot(b, b)), rtol=1e-6)
