"""AOT bridge: lower the L2 model (with the L1 Pallas kernel inlined)
to HLO **text** and write it into artifacts/ for the Rust runtime.

HLO text — NOT `lowered.compile()` or serialized protos — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot --out ../artifacts [--grid 64]
Outputs:
  artifacts/cg_step.hlo.txt   one CG iteration (tuple of 4 outputs)
  artifacts/spmv.hlo.txt      bare SpMV
  artifacts/manifest.json     shapes + provenance the runtime checks
"""

from __future__ import annotations

import argparse
import json
import os

import jax

from . import model
from .kernels.spmv_ell import mxu_flops_per_step, vmem_bytes


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(grid: int):
    """Lower both entry points for a grid×grid Laplacian problem."""
    br = bc = grid
    n = grid * grid
    nbr = n // br
    k = 3
    args = model.shapes(nbr, k, br, bc, n)
    cg_text = to_hlo_text(jax.jit(model.cg_step).lower(*args))
    spmv_text = to_hlo_text(jax.jit(model.spmv).lower(*args[:2], args[4]))
    manifest = {
        "version": 1,
        "grid": grid,
        "n": n,
        "nbr": nbr,
        "k": k,
        "br": br,
        "bc": bc,
        "dtype": "f32",
        "entries": {
            "cg_step": {
                "file": "cg_step.hlo.txt",
                "inputs": ["data", "idx", "x", "r", "p", "rr"],
                "outputs": ["x", "r", "p", "rr"],
            },
            "spmv": {
                "file": "spmv.hlo.txt",
                "inputs": ["data", "idx", "x"],
                "outputs": ["y"],
            },
        },
        "perf_model": {
            "vmem_bytes_per_step": vmem_bytes(nbr, k, br, bc, n),
            "mxu_flops_per_step": mxu_flops_per_step(k, br, bc, rows_per_step=min(nbr, 16)),
            "grid_steps": nbr // min(nbr, 16),
        },
    }
    return cg_text, spmv_text, manifest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--grid", type=int, default=64, help="grid width (n = grid^2)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    cg_text, spmv_text, manifest = build(args.grid)
    cg_path = os.path.join(args.out, "cg_step.hlo.txt")
    with open(cg_path, "w") as f:
        f.write(cg_text)
    with open(os.path.join(args.out, "spmv.hlo.txt"), "w") as f:
        f.write(spmv_text)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {cg_path} ({len(cg_text)} chars) + spmv + manifest")


if __name__ == "__main__":
    main()
