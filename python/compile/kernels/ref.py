"""Pure-jnp correctness oracles for the L1 kernel and the L2 model.

Everything here is straight-line jnp with no Pallas — the reference the
pytest suite asserts the kernel against (`assert_allclose`), and the
ground truth for the dense conversion used in property tests.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def spmv_ref(data, idx, x):
    """Block-ELL SpMV via gather + einsum (no Pallas)."""
    nbr, k, br, bc = data.shape
    xb = x.reshape(-1, bc)          # (nbc, BC)
    gathered = xb[idx]              # (nbr, K, BC)
    y = jnp.einsum("nkrc,nkc->nr", data, gathered)
    return y.reshape(nbr * br)


def ell_to_dense(data, idx, n_cols):
    """Materialize the block-ELL matrix as dense (numpy, tests only)."""
    data = np.asarray(data)
    idx = np.asarray(idx)
    nbr, k, br, bc = data.shape
    out = np.zeros((nbr * br, n_cols), dtype=data.dtype)
    for i in range(nbr):
        for j in range(k):
            c = int(idx[i, j]) * bc
            out[i * br:(i + 1) * br, c:c + bc] += data[i, j]
    return out


def cg_step_ref(data, idx, x, r, p, rr):
    """One CG iteration (Barrett et al. [25]), pure jnp."""
    ap = spmv_ref(data, idx, p)
    alpha = rr / jnp.dot(p, ap)
    x2 = x + alpha * p
    r2 = r - alpha * ap
    rr2 = jnp.dot(r2, r2)
    beta = rr2 / rr
    p2 = r2 + beta * p
    return x2, r2, p2, rr2


def laplacian_2d_block_ell(grid: int, br: int | None = None):
    """The 5-point 2-D Laplacian on a grid×grid mesh in block-ELL form.

    Uses BR = BC = grid so each block row is one grid row; the stencil
    then touches exactly the block columns {i-1, i, i+1} -> K = 3.
    Mirrors `linalg::laplacian_2d` on the Rust side (same matrix, same
    ordering), which is what makes the cross-layer CG comparison exact.
    """
    br = br or grid
    assert br == grid, "block size must equal the grid width for K=3"
    n = grid * grid
    nbr = n // br
    k = 3
    data = np.zeros((nbr, k, br, br), dtype=np.float32)
    idx = np.zeros((nbr, k), dtype=np.int32)
    # In-block stencil: tridiagonal [-1, 4, -1] along the grid row.
    diag = (
        4.0 * np.eye(br, dtype=np.float32)
        - np.eye(br, k=1, dtype=np.float32)
        - np.eye(br, k=-1, dtype=np.float32)
    )
    off = -np.eye(br, dtype=np.float32)
    for i in range(nbr):
        # Slot 0: block column i-1 (pad: idx 0 with zero block).
        if i > 0:
            idx[i, 0] = i - 1
            data[i, 0] = off
        # Slot 1: the diagonal block.
        idx[i, 1] = i
        data[i, 1] = diag
        # Slot 2: block column i+1.
        if i + 1 < nbr:
            idx[i, 2] = i + 1
            data[i, 2] = off
    return data, idx
