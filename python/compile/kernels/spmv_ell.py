"""Layer-1: block-ELL SpMV as a Pallas kernel.

The paper's application hot-spot is the CG sweep over a 5.4G-nnz sparse
matrix (§V-A).  On TPU-class hardware the natural sparse format is
**block-ELL**: the matrix is cut into `BR×BC` dense blocks; each block
row stores exactly `K` blocks (zero-padded) plus their block-column
indices.  Dense `BR×BC` tiles feed the MXU systolic array, and the
`BlockSpec` grid expresses the HBM→VMEM schedule over groups of block
rows — the TPU rethink of what a CUDA kernel would do with warps over
CSR (DESIGN.md §Hardware-Adaptation).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (numerically identical;
real-TPU performance is *estimated* from the VMEM/MXU structure, see
EXPERIMENTS.md §Perf-L1).

§Perf-L1 note: the kernel body is ONE gather + ONE `dot_general`
contraction per grid step (not a per-block loop of dynamic slices) and
each grid step covers `rows_per_step` block rows.  Under interpret mode
every grid step costs ~0.8 ms of harness overhead, so coarsening the
grid 64→4 steps cut the AOT artifact's per-call latency ~10×; on a real
TPU the same shape keeps the MXU fed with (K·BC)-deep contractions
while staying far under the VMEM budget.

VMEM footprint per grid step (f32, defaults nbr=64, K=3, BR=BC=64,
rows_per_step=16):
    data tile   rows·K·BR·BC·4 = 768 KiB
    x (resident)           n·4 =  16 KiB
    y tile           rows·BR·4 =   4 KiB
— comfortably below the ~16 MiB VMEM budget, with room to push BR/BC to
the MXU-optimal 128×128 for larger problems.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(idx_ref, data_ref, x_ref, y_ref, *, bc: int):
    """`rows_per_step` block rows per grid step:
    y[i] = Σ_k data[i,k] @ x[idx[i,k]] as gather + one contraction."""
    idx = idx_ref[...]                  # (rows, K)
    data = data_ref[...]                # (rows, K, BR, BC)
    xb = x_ref[...].reshape(-1, bc)     # (nbc, BC)
    gathered = xb[idx]                  # (rows, K, BC) — one gather
    # Contract over (K, BC): feeds the MXU as a batched matvec.
    y_ref[...] = jnp.einsum("nkrc,nkc->nr", data, gathered)


def spmv_block_ell(data: jax.Array, idx: jax.Array, x: jax.Array,
                   *, rows_per_step: int | None = None,
                   interpret: bool = True) -> jax.Array:
    """y = A·x for a block-ELL matrix.

    Args:
      data: (nbr, K, BR, BC) f32 — dense blocks (zero-padded).
      idx:  (nbr, K) i32 — block-column index per block (pad → 0,
            paired with an all-zero block so the contribution vanishes).
      x:    (n,) f32 with n == nbc·BC.
      rows_per_step: block rows per grid step (None → min(nbr, 16);
            must divide nbr).

    Returns: (n_rows,) f32 with n_rows == nbr·BR.
    """
    nbr, k, br, bc = data.shape
    n = x.shape[0]
    assert n % bc == 0, "x length must be a multiple of BC"
    rows = rows_per_step or min(nbr, 16)
    assert nbr % rows == 0, f"rows_per_step {rows} must divide nbr {nbr}"
    out = pl.pallas_call(
        functools.partial(_spmv_kernel, bc=bc),
        grid=(nbr // rows,),
        in_specs=[
            pl.BlockSpec((rows, k), lambda i: (i, 0)),
            pl.BlockSpec((rows, k, br, bc), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),  # x stays resident
        ],
        out_specs=pl.BlockSpec((rows, br), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbr, br), jnp.float32),
        interpret=interpret,
    )(idx, data, x)
    return out.reshape(nbr * br)


def vmem_bytes(nbr: int, k: int, br: int, bc: int, n: int,
               rows_per_step: int | None = None) -> int:
    """VMEM footprint of one grid step (see module docstring)."""
    rows = rows_per_step or min(nbr, 16)
    return 4 * (rows * k * br * bc + n + rows * br + rows * k)


def mxu_flops_per_step(k: int, br: int, bc: int,
                       rows_per_step: int = 16) -> int:
    """MXU work per grid step: rows·K matvecs of BR×BC."""
    return 2 * rows_per_step * k * br * bc
