"""Layer-2: the JAX compute graph — one CG iteration over the
block-ELL matrix, calling the L1 Pallas kernel for the SpMV hot-spot.

This is the function `aot.py` lowers once to HLO text; the Rust
runtime (`rust/src/runtime/`) loads and executes it on the PJRT CPU
client for every iteration of the end-to-end example.  Python never
runs at simulation/serving time.

State threading (functional, donation-friendly): the full CG state
(x, r, p, rr) flows in and out, so XLA can reuse the buffers; the
scalar `rr` rides along to avoid a host round-trip per iteration.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.spmv_ell import spmv_block_ell


def cg_step(data, idx, x, r, p, rr):
    """One CG iteration; returns (x', r', p', rr')."""
    ap = spmv_block_ell(data, idx, p)
    alpha = rr / jnp.dot(p, ap)
    x2 = x + alpha * p
    r2 = r - alpha * ap
    rr2 = jnp.dot(r2, r2)
    beta = rr2 / rr
    p2 = r2 + beta * p
    return x2, r2, p2, rr2


def spmv(data, idx, x):
    """Bare SpMV entry point (microbench + quickstart artifact)."""
    return spmv_block_ell(data, idx, x)


def cg_state_init(data, idx, b):
    """CG initialization from x0 = 0: r = p = b, rr = b.b."""
    x = jnp.zeros_like(b)
    rr = jnp.dot(b, b)
    return x, b, b, rr


def shapes(nbr: int, k: int, br: int, bc: int, n: int):
    """ShapeDtypeStructs of (data, idx, x, r, p, rr) for lowering."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((nbr, k, br, bc), f32),
        jax.ShapeDtypeStruct((nbr, k), jnp.int32),
        jax.ShapeDtypeStruct((n,), f32),
        jax.ShapeDtypeStruct((n,), f32),
        jax.ShapeDtypeStruct((n,), f32),
        jax.ShapeDtypeStruct((), f32),
    )
