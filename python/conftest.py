"""Make `from compile import ...` resolve regardless of the pytest
invocation directory (`python -m pytest python/tests` from the repo
root, or `pytest tests` from `python/`)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
