//! RMS-driven malleability: the scenario that motivates the paper's
//! §I — a cluster where a malleable job donates and reclaims nodes as
//! rigid jobs come and go (Adaptive = MakeRoom + FillIdle), and where
//! the *cost* of each reconfiguration
//! is what the redistribution method determines.
//!
//! The driver replays a small arrival trace twice — once with a rigid
//! job (no resizing) and once with a malleable job under the FillIdle/
//! MakeRoom policies — and reports utilization plus the redistribution
//! cost of every resize for COL vs RMA-Lockall (blocking, as the RMS
//! blocks the app during its checkpoint).
//!
//! ```sh
//! cargo run --release --example rms_scheduler
//! ```

use proteo::mam::{Method, Strategy};
use proteo::proteo::{run_once, RunSpec};
use proteo::rms::{Policy, Rms};
use proteo::sam::SamConfig;

/// (arrival step, cores, duration in steps) of rigid background jobs.
const TRACE: &[(usize, usize, usize)] = &[(2, 60, 4), (4, 40, 3), (9, 100, 3)];
const STEPS: usize = 16;
const CLUSTER: usize = 160;

fn redistribution_cost(ns: usize, nd: usize, method: Method) -> f64 {
    let mut spec = RunSpec::sarteco25(ns, nd, method, Strategy::Blocking);
    // Smaller problem: the scheduler story is about *relative* costs.
    spec.sam = SamConfig::sarteco25();
    spec.sam.matrix_elems /= 10;
    spec.sam.colind_elems /= 10;
    spec.sam.rowptr_elems /= 10;
    spec.sam.vector_elems /= 10;
    spec.sam.flops_per_iter /= 10.0;
    spec.warmup_iters = 1;
    spec.post_iters = 1;
    run_once(&spec).redist_time
}

fn simulate(malleable: bool) -> (f64, Vec<(usize, usize)>) {
    let policy = if malleable { Policy::Adaptive } else { Policy::Static };
    let mut rms = Rms::new(CLUSTER, 20, policy);
    let job = if malleable {
        rms.submit("malleable-cg", 60, 20, 160)
    } else {
        rms.submit("rigid-cg", 60, 60, 60)
    };
    let mut running: Vec<(usize, usize)> = Vec::new(); // (id, ends_at)
    let mut resizes = Vec::new();
    let mut util_acc = 0.0;
    for step in 0..STEPS {
        // Arrivals.
        for &(at, cores, dur) in TRACE {
            if at == step {
                let id = rms.submit(&format!("rigid@{at}"), cores, cores, cores);
                running.push((id, step + dur));
            }
        }
        // Departures.
        for (id, ends) in running.clone() {
            if ends == step {
                rms.finish(id);
                running.retain(|&(j, _)| j != id);
            }
        }
        // Malleable checkpoint: shrink to admit, grow into idle space.
        if let Some(d) = rms.checkpoint_decision(job) {
            resizes.push((d.from, d.to));
            rms.apply(d);
        }
        util_acc += rms.utilization();
    }
    (util_acc / STEPS as f64, resizes)
}

fn main() {
    let (rigid_util, _) = simulate(false);
    let (mall_util, resizes) = simulate(true);
    println!("== cluster utilization over {STEPS} scheduling steps ==");
    println!("  rigid job:      {:>5.1} %", rigid_util * 100.0);
    println!("  malleable job:  {:>5.1} %", mall_util * 100.0);
    println!("  resizes driven by the RMS: {resizes:?}");
    println!();
    println!("== redistribution cost of each resize (blocking, §V-B) ==");
    println!("{:<12}{:>14}{:>16}{:>10}", "resize", "COL", "RMA-Lockall", "ratio");
    for &(from, to) in &resizes {
        let col = redistribution_cost(from, to, Method::Collective);
        let rma = redistribution_cost(from, to, Method::RmaLockall);
        println!(
            "{:<12}{:>12.3}s{:>14.3}s{:>9.2}x",
            format!("{from}->{to}"),
            col,
            rma,
            col / rma
        );
    }
    println!();
    println!(
        "malleability buys {:.1} utilization points; the paper's question is \
         whether one-sided redistribution makes each resize cheaper — \
         the ratios above reproduce its answer (no: 0.73-0.99x).",
        (mall_util - rigid_util) * 100.0
    );
}
