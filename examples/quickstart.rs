//! Quickstart: make an application malleable in ~40 lines.
//!
//! A 4-rank job registers two data structures, runs a few iterations,
//! grows to 6 ranks in the background (Wait Drains) while continuing to
//! iterate, and keeps solving on the new size.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use proteo::mam::{
    block_of, DataKind, Mam, MamStatus, Method, PlannerMode, ReconfigCfg, Registry, SpawnStrategy,
    Strategy, WinPoolPolicy,
};
use proteo::netmodel::{NetParams, Topology};
use proteo::simmpi::{CommId, MpiProc, MpiSim, Payload, WORLD};

fn main() {
    let (ns, nd, total) = (4usize, 6usize, 60_000u64);
    let mut sim = MpiSim::new(Topology::new(2, 4), NetParams::sarteco25());
    let world = sim.world();

    sim.launch(ns, move |p: MpiProc| {
        let rank = p.rank(WORLD);
        // 1. Register the distributed data once (MaM's automatic mode).
        let mut reg = Registry::new();
        let blk = block_of(total, ns, rank);
        reg.register("field", DataKind::Constant, total, Payload::virt(blk.len()));
        let vb = block_of(total / 10, ns, rank);
        reg.register("state", DataKind::Variable, total / 10, Payload::virt(vb.len()));
        let decls = reg.decls();

        // 2. Create the malleability handle.
        let cfg = ReconfigCfg {
            method: Method::Collective,
            strategy: Strategy::WaitDrains,
            spawn_cost: 0.05,
            spawn_strategy: SpawnStrategy::Sequential,
            win_pool: WinPoolPolicy::off(),
            rma_chunk_kib: 0,
            planner: PlannerMode::Fixed,
        };
        let mut mam = Mam::new(reg, cfg.clone());

        // 3. Application loop with a resize checkpoint.
        for _ in 0..3 {
            p.compute(0.01); // "the app works"
            let _ = p.allgather(WORLD, Payload::virt(1));
            p.iter_tick();
        }

        // 4. Resize: spawned ranks run drain_join then join the app.
        let cfg2 = cfg.clone();
        let drain_body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
            Arc::new(move |dp: MpiProc, merged: CommId| {
                let dmam = Mam::drain_join(&dp, merged, ns, nd, &decls, cfg2.clone());
                assert!(dmam.registry.verify_blocks(nd, dp.rank(merged)).is_empty());
                for _ in 0..2 {
                    dp.compute(0.01);
                    let _ = dp.allgather(merged, Payload::virt(1));
                    dp.iter_tick();
                }
            });
        let mut status = mam.reconfigure(&p, WORLD, nd, drain_body);
        while status == MamStatus::InProgress {
            p.compute(0.01); // the app keeps iterating in the background
            let _ = p.allgather(WORLD, Payload::real(vec![1.0]));
            p.iter_tick();
            status = mam.checkpoint(&p);
        }
        let out = mam.finish(&p, WORLD);

        // 5. Continue on the new communicator (all ranks kept: grow).
        let comm = out.app_comm.expect("grow keeps every source");
        assert!(mam.registry.verify_blocks(nd, p.rank(comm)).is_empty());
        for _ in 0..2 {
            p.compute(0.01);
            let _ = p.allgather(comm, Payload::virt(1));
            p.iter_tick();
        }
        if rank == 0 {
            println!("rank 0: resized {ns} -> {nd}, registry verified on the new layout");
        }
    });

    let end = sim.run().expect("simulation");
    let w = world.lock().unwrap();
    println!(
        "done at t={end:.3}s virtual; redistribution took {:.3}s",
        w.metrics.span("mam.redist_start", "mam.redist_end").unwrap_or(f64::NAN)
    );
}
