//! End-to-end driver: a **real** Conjugate-Gradient solve runs through
//! every layer of the stack while the job is resized mid-solve.
//!
//! * L1/L2 — each CG iteration executes the AOT-compiled JAX/Pallas
//!   `cg_step` artifact on the PJRT CPU client (Python never runs).
//! * L3 — the same problem's CSR arrays are sharded over a simulated
//!   NS-rank job; at a checkpoint MaM reconfigures it to ND ranks with
//!   RMA-Lockall + Wait Drains, redistributing the *actual bytes*.
//!   After the resize the matrix is reassembled from the drain shards
//!   and the PJRT solve continues on it.
//!
//! If the redistribution corrupted a single element, the reassembled
//! matrix would differ and the residual history would diverge from the
//! uninterrupted reference solve — the final assertion checks exactly
//! that.  Run with `make artifacts && cargo run --release --example
//! cg_reconfigure`; results are recorded in EXPERIMENTS.md.

use std::sync::{Arc, Mutex};

use proteo::linalg::{self, EllMatrix};
use proteo::mam::{
    block_of, DataKind, Mam, MamStatus, Method, PlannerMode, ReconfigCfg, Registry, SpawnStrategy,
    Strategy, WinPoolPolicy,
};
use proteo::netmodel::{NetParams, Topology};
use proteo::runtime::{artifacts_dir, runtime_available, CgRuntime, CgState};
use proteo::simmpi::{CommId, MpiProc, MpiSim, Payload, WORLD};

const NS: usize = 4;
const ND: usize = 8;
const RECONF_AT_ITER: usize = 12;

fn main() {
    if !runtime_available() {
        eprintln!("PJRT runtime unavailable — run `make artifacts` and build with `--features pjrt`");
        std::process::exit(2);
    }
    let rt = CgRuntime::load(artifacts_dir()).expect("load artifacts");
    let grid = rt.manifest.grid;
    let n = rt.manifest.n;
    println!("== end-to-end: CG(n={n}) through PJRT + mid-solve resize {NS}->{ND} ==");
    println!("platform={}, artifact blocks=({}, {}, {}, {})",
        rt.platform(), rt.manifest.nbr, rt.manifest.k, rt.manifest.br, rt.manifest.bc);

    // ---- The real problem.
    let csr = linalg::laplacian_2d(grid);
    let ell = EllMatrix::laplacian_2d(grid);
    let b: Vec<f32> = (0..n).map(|i| 1.0 + ((i % 11) as f32) * 0.0625).collect();

    // ---- Reference: uninterrupted PJRT solve.
    let (_, ref_hist) = rt.cg_solve(&ell, &b, 1e-6, 300).expect("reference solve");
    println!("reference solve: {} iterations to 1e-6", ref_hist.len() - 1);

    // ---- Simulated malleable job owning the real data.
    // Registry entries carry the actual f32 data widened to f64 (the
    // payload element type); total = element counts of each array.
    let data64: Vec<f64> = ell.data.iter().map(|&v| f64::from(v)).collect();
    let idx64: Vec<f64> = ell.idx.iter().map(|&v| f64::from(v)).collect();
    let x64: Vec<f64> = b.iter().map(|&v| f64::from(v)).collect();
    let totals = (data64.len() as u64, idx64.len() as u64, x64.len() as u64);
    let shards: Arc<Mutex<Vec<Option<(Vec<f64>, Vec<f64>, Vec<f64>)>>>> =
        Arc::new(Mutex::new(vec![None; ND]));

    let data_arc = Arc::new(data64);
    let idx_arc = Arc::new(idx64);
    let x_arc = Arc::new(x64);
    let shards2 = shards.clone();

    let mut sim = MpiSim::new(Topology::new_cyclic(2, ND / 2 + NS), NetParams::sarteco25());
    let world = sim.world();
    sim.launch(NS, move |p: MpiProc| {
        let rank = p.rank(WORLD);
        let slice_of = |v: &[f64], total: u64, nranks: usize, r: usize| -> Vec<f64> {
            let blk = block_of(total, nranks, r);
            v[blk.ini as usize..blk.end as usize].to_vec()
        };
        let mut reg = Registry::new();
        reg.register("A_vals", DataKind::Constant, totals.0,
            Payload::real(slice_of(&data_arc, totals.0, NS, rank)));
        reg.register("A_idx", DataKind::Constant, totals.1,
            Payload::real(slice_of(&idx_arc, totals.1, NS, rank)));
        reg.register("x", DataKind::Variable, totals.2,
            Payload::real(slice_of(&x_arc, totals.2, NS, rank)));
        let decls = reg.decls();
        // Window pool on: the real-data end-to-end path exercises the
        // §VI warm-acquire machinery (bit-exactness is asserted below).
        let cfg = ReconfigCfg {
            method: Method::RmaLockall,
            strategy: Strategy::WaitDrains,
            spawn_cost: 0.1,
            spawn_strategy: SpawnStrategy::Sequential,
            win_pool: WinPoolPolicy::on(),
            rma_chunk_kib: 0,
            planner: PlannerMode::Fixed,
        };
        let mut mam = Mam::new(reg, cfg.clone());

        // Emulated CG iterations before the resize checkpoint.
        for _ in 0..RECONF_AT_ITER {
            p.compute(0.02);
            let _ = p.allgather(WORLD, Payload::virt(2));
            p.iter_tick();
        }

        // ---- Reconfigure NS -> ND while iterating.
        let shards3 = shards2.clone();
        let cfg2 = cfg.clone();
        let decls2 = decls.clone();
        let drain_body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
            Arc::new(move |dp: MpiProc, merged: CommId| {
                let dmam = Mam::drain_join(&dp, merged, NS, ND, &decls2, cfg2.clone());
                let dr = dp.rank(merged);
                let take = |name: &str| {
                    dmam.registry.by_name(name).unwrap().local.as_slice().unwrap().to_vec()
                };
                shards3.lock().unwrap()[dr] =
                    Some((take("A_vals"), take("A_idx"), take("x")));
                // keep iterating with the sources after the switch
                for _ in 0..3 {
                    dp.compute(0.01);
                    let _ = dp.allgather(merged, Payload::virt(2));
                    dp.iter_tick();
                }
            });
        let mut status = mam.reconfigure(&p, WORLD, ND, drain_body);
        let mut overlapped = 0u64;
        while status == MamStatus::InProgress {
            p.compute(0.02);
            let _ = p.allgather(WORLD, Payload::real(vec![1.0]));
            p.iter_tick();
            overlapped += 1;
            status = mam.checkpoint(&p);
        }
        p.metrics(|m| m.mark_max("ex.overlapped", overlapped as f64));
        let out = mam.finish(&p, WORLD);
        if let Some(comm) = out.app_comm {
            let nr = p.rank(comm);
            let take = |name: &str| {
                mam.registry.by_name(name).unwrap().local.as_slice().unwrap().to_vec()
            };
            shards2.lock().unwrap()[nr] = Some((take("A_vals"), take("A_idx"), take("x")));
            for _ in 0..3 {
                p.compute(0.01);
                let _ = p.allgather(comm, Payload::virt(2));
                p.iter_tick();
            }
        }
    });
    let virt_end = sim.run().expect("simulation");
    let (r_time, overlapped) = {
        let w = world.lock().unwrap();
        (
            w.metrics.span("mam.redist_start", "mam.redist_end").unwrap_or(f64::NAN),
            w.metrics.mark_at("ex.overlapped").unwrap_or(0.0),
        )
    };
    println!(
        "simulated resize: R={r_time:.3}s virtual, {overlapped} overlapped iterations, end t={virt_end:.3}s"
    );

    // ---- Reassemble the matrix from the ND drain shards and verify.
    let mut data2 = Vec::with_capacity(ell.data.len());
    let mut idx2 = Vec::with_capacity(ell.idx.len());
    let mut x2 = Vec::with_capacity(n);
    {
        let sh = shards.lock().unwrap();
        for r in 0..ND {
            let (d, i, x) = sh[r].as_ref().expect("missing drain shard");
            data2.extend(d.iter().map(|&v| v as f32));
            idx2.extend(i.iter().map(|&v| v as i32));
            x2.extend(x.iter().map(|&v| v as f32));
        }
    }
    assert_eq!(data2, ell.data, "A_vals corrupted by redistribution");
    assert_eq!(idx2, ell.idx, "A_idx corrupted by redistribution");
    assert_eq!(x2, b, "x corrupted by redistribution");
    println!("redistribution preserved all {} bytes bit-for-bit",
        (data2.len() * 4 + idx2.len() * 4 + x2.len() * 4));

    // ---- Continue the solve on the REASSEMBLED matrix via PJRT.
    let ell2 = EllMatrix { nbr: ell.nbr, k: ell.k, br: ell.br, bc: ell.bc,
        data: data2, idx: idx2 };
    let (_, hist2) = rt.cg_solve(&ell2, &x2, 1e-6, 300).expect("post-resize solve");
    assert_eq!(
        ref_hist.len(),
        hist2.len(),
        "residual history diverged after the resize"
    );
    for (a, bb) in ref_hist.iter().zip(&hist2) {
        assert!((a - bb).abs() <= 1e-6 + a * 1e-4, "history mismatch: {a} vs {bb}");
    }
    println!(
        "post-resize PJRT solve reproduces the reference exactly: {} iterations, final rel residual {:.3e}",
        hist2.len() - 1,
        hist2.last().unwrap()
    );

    // ---- Cross-check against the pure-Rust f64 CG.
    let bd: Vec<f64> = b.iter().map(|&v| f64::from(v)).collect();
    let mut xs = vec![0.0; n];
    let trace = linalg::cg(&csr, &bd, &mut xs, 1e-6, 300);
    println!(
        "rust f64 CG: {} iterations (PJRT f32: {}) — all layers agree",
        trace.iterations,
        hist2.len() - 1
    );
    println!("OK");
}
