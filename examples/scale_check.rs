use proteo::mam::{Method, Strategy};
use proteo::proteo::{run_once, RunSpec};

fn main() {
    for (ns, nd) in [(20usize, 160usize), (160, 20), (160, 40)] {
        for (m, s) in [
            (Method::Collective, Strategy::Blocking),
            (Method::RmaLock, Strategy::Blocking),
            (Method::RmaLockall, Strategy::Blocking),
            (Method::Collective, Strategy::NonBlocking),
            (Method::Collective, Strategy::WaitDrains),
            (Method::RmaLock, Strategy::WaitDrains),
            (Method::RmaLockall, Strategy::WaitDrains),
            (Method::Collective, Strategy::Threading),
            (Method::RmaLock, Strategy::Threading),
        ] {
            let t0 = std::time::Instant::now();
            let spec = RunSpec::sarteco25(ns, nd, m, s);
            let r = run_once(&spec);
            println!(
                "{:>3}->{:<3} {:<16} R={:>8.3}s n_it={:>4} t_base={:.3} t_bg={:.3} omega={:>7.2} t_nd={:.3}  [wall {:.2}s, {} events]",
                ns, nd, r.label, r.redist_time, r.n_it, r.t_base, r.t_bg, r.omega, r.t_it_nd,
                t0.elapsed().as_secs_f64(), r.events
            );
        }
        println!();
    }
}
